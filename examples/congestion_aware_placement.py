"""Congestion-aware multi-tenant placement (Segal et al. 2022 objective).

T tenants share one datacenter reduction tree. Each tenant's SOAR placement
is individually utilization-optimal, but independently optimal placements
pile messages onto the same links — the *max-link congestion* across the
fleet can be far above what a coordinated assignment achieves. The
repeated-solve congestion driver (`repro.engine.solve_congestion`)
re-solves the whole tenant batch under penalty-reweighted link rates until
the hottest link stops improving, keeping the best placement seen. By
default the whole round loop runs on the accelerator as one jitted
`lax.while_loop` — only the best masks and the scalar history come back
(`bytes_to_host` below); `device_loop=False` runs the bit-identical
host-driven reference.

Run:  python examples/congestion_aware_placement.py
      (or PYTHONPATH=src python examples/congestion_aware_placement.py from
       a source checkout without `pip install -e .`)
"""
import numpy as np

from repro.core import bt, phi
from repro.core.tree import sample_load
from repro.engine import solve_batch, solve_congestion

N_TOTAL = 128      # BT(128) datacenter tree
K = 8              # per-tenant blue budget
T = 16             # tenants sharing the tree

t = bt(N_TOTAL, "constant")
loads = [sample_load(t, "power-law", seed=s) for s in range(T)]

res = solve_congestion(t, loads, K)

print(f"BT({N_TOTAL}), {T} tenants, k={K}, power-law loads\n")
print(f"{'round':<6} {'max-link congestion':<20}")
for r, cmax in enumerate(res.history):
    tag = "  <- best" if r == res.best_round else ""
    print(f"{r:<6} {cmax:<20.0f}{tag}")

base = solve_batch([t] * T, loads, K)
util_only = base.costs.sum()
print(f"\nmax-link congestion: {res.baseline_max:.0f} (utilization-only) "
      f"-> {res.max_congestion:.0f} "
      f"({100 * res.improvement:.1f}% reduction, {res.rounds} rounds, "
      f"{res.bytes_to_host} bytes device->host for the whole loop)")
print(f"total utilization:   {util_only:.1f} (utilization-only) "
      f"-> {res.costs.sum():.1f} "
      f"(+{100 * (res.costs.sum() / util_only - 1):.2f}% — the price of "
      "spreading)")

# every per-tenant placement is still a valid budget-k SOAR placement,
# costed on the ORIGINAL rho
for ti, L in enumerate(loads):
    assert res.blue[ti].sum() <= K
    assert res.costs[ti] == phi(t, L, res.blue[ti])
print("\nEach tenant keeps a valid (at most k blue) placement; the driver "
      "trades a few\npercent of summed utilization for a much cooler "
      "hottest link.")
