"""Quickstart: the paper's core in one page.

Builds the Fig. 2 example tree and a BT(256) datacenter tree, runs SOAR
and every contending strategy, and prints the utilization table.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (STRATEGIES, all_blue, all_red, bt, phi, sample_load,
                        soar, soar_fast)
from repro.core.tree import DEST, Tree

# --- 1. The paper's worked example (Fig. 2/3) ------------------------------
parent = np.array([DEST, 0, 0, 1, 1, 2, 2])   # complete binary tree, 7 switches
tree = Tree(parent, np.ones(7))               # unit link rates
load = np.zeros(7, dtype=np.int64)
load[[3, 4, 5, 6]] = [2, 6, 5, 4]             # rack sizes at the leaves

print("Fig. 2 tree, k = 2 aggregation switches:")
for name, fn in STRATEGIES.items():
    cost = phi(tree, load, fn(tree, load, 2))
    print(f"  {name:<12} phi = {cost:.0f}")
res = soar(tree, load, 2)
print(f"  {'SOAR':<12} phi = {res.cost:.0f}  (optimal; blue = "
      f"{sorted(map(int, np.nonzero(res.blue)[0]))})")
print(f"  {'all-red':<12} phi = {phi(tree, load, all_red(tree)):.0f}")
print(f"  {'all-blue':<12} phi = {phi(tree, load, all_blue(tree)):.0f}\n")

# --- 2. A datacenter-scale tree --------------------------------------------
t = bt(256, "exponential")                    # BT(256), rates double per level
L = sample_load(t, "power-law", seed=0)
red = phi(t, L, all_red(t))
print("BT(256), exponential link rates, power-law rack loads:")
print(f"  all-red utilization : {red:.0f}")
for k in (4, 16, 64):
    r = soar_fast(t, L, k)
    print(f"  SOAR k={k:<3}         : {r.cost:.0f}  "
          f"({100 * (1 - r.cost / red):.0f}% saved, "
          f"{int(r.blue.sum())} blue switches)")
