"""Fault-tolerance walk-through: failures, stragglers, checkpoint resume.

Demonstrates the full runtime story on 8 simulated devices:
  1. train with SOAR-scheduled reduction, checkpointing every 5 steps;
  2. two chips die mid-run -> orchestrator re-sows the blue placement and
     training continues on the survivors;
  3. the process "crashes" (we stop), then resumes exactly from the last
     checkpoint;
  4. a persistent straggler is quarantined by the deadline policy;
  5. chaos: a seeded fault scenario (switch/link faults, rack failures,
     straggler storms) drives the orchestrator through the preplan cache
     with every safety invariant checked after each event;
  6. partial-capacity degradation: a blue switch loses half its
     aggregation plane — the instant degraded program spills its overflow
     one hop up (bounded regression, no solve), then the replan lands;
  7. (--train-chaos N) training-coupled chaos: every event drives a real
     optimizer step, lossless recoveries are asserted *bit-identical* to
     the fault-free program, crashes restart from the checkpoint; writes
     experiments/bench/chaos_train_report.json.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
      [--skip-training] [--chaos N] [--train-chaos N] [--seed S]
(The script re-executes itself with XLA_FLAGS so the 8 fake devices are
installed before jax initializes.)
"""
import argparse
import os
import shutil
import subprocess
import sys

FLAG = "--xla_force_host_platform_device_count=8"

if os.environ.get("XLA_FLAGS", "") != FLAG:
    env = {**os.environ, "XLA_FLAGS": FLAG,
           "PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
    sys.exit(subprocess.run([sys.executable, __file__] + sys.argv[1:],
                            env=env).returncode)

import numpy as np  # noqa: E402

from repro.launch import train  # noqa: E402
from repro.runtime import (ChaosHarness, Orchestrator,  # noqa: E402
                           OrchestratorConfig, generate_scenario)
from repro.collectives import chip_level_tree, fleet_tree  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--skip-training", action="store_true",
                help="skip phases 1-2 (the actual training runs)")
ap.add_argument("--chaos", type=int, default=20, metavar="N",
                help="number of chaos events in phase 5 (0 disables)")
ap.add_argument("--train-chaos", type=int, default=0, metavar="N",
                help="number of training-coupled chaos events in phase 7 "
                     "(0 disables)")
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

CKPT = "/tmp/repro_ft_ckpt"

if not args.skip_training:
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=" * 64)
    print("Phase 1: train 12 steps; chips 3 and 6 fail at steps 5 and 8")
    print("=" * 64)
    train.main([
        "--arch", "granite-20b", "--reduced", "--steps", "12",
        "--global-batch", "8", "--seq", "64", "--k", "2",
        "--fail", "5:3;8:6", "--ckpt-dir", CKPT, "--ckpt-every", "5",
        "--log-every", "3",
    ])

    print()
    print("=" * 64)
    print("Phase 2: 'crash' and resume from the latest checkpoint")
    print("=" * 64)
    train.main([
        "--arch", "granite-20b", "--reduced", "--steps", "18",
        "--global-batch", "8", "--seq", "64", "--k", "2",
        "--ckpt-dir", CKPT, "--resume", "--log-every", "3",
    ])
    print()

print("=" * 64)
print("Phase 3: straggler quarantine (policy demo, no training)")
print("=" * 64)
topo = chip_level_tree(n_pods=2, racks_per_pod=2, chips_per_rack=2)
orch = Orchestrator(topo, OrchestratorConfig(k=2, straggler_patience=2))
print(f"initial phi = {orch.program.utilization:.0f}")
durations = np.ones(8)
durations[5] = 8.0          # device 5 is persistently 8x slower
for step in range(3):
    rep = orch.on_step_durations(durations)
    print(f"step {step}: suspects={np.nonzero(rep.suspects)[0].tolist()} "
          f"quarantined={np.nonzero(rep.quarantined)[0].tolist()}")
print(f"after quarantine: alive={orch.n_alive}, replans={orch.replans}, "
      f"phi={orch.program.utilization:.0f}")
orch.on_recover([5])
print(f"after recovery : alive={orch.n_alive}, "
      f"phi={orch.program.utilization:.0f}")

print()
print("=" * 64)
print("Phase 4: switch/link fault domains + preplanned fast recovery")
print("=" * 64)
topo = fleet_tree(n_pods=2, racks_per_pod=2, chips_per_rack=4)
orch = Orchestrator(topo, OrchestratorConfig(k=3, capacity=2))
print(f"initial phi = {orch.program.utilization:.0f}, "
      f"blue = {np.nonzero(orch.blue)[0].tolist()}")
orch.preplan_switch_failures()      # one batched solve for all scenarios
s = int(np.nonzero(orch.blue)[0][0])
orch.on_switch_failure([s])         # aggregation plane dies, forwarding lives
ev = orch.degraded_events[-1]
print(f"switch {s} fails: degraded phi = {ev['degraded_utilization']:.0f} "
      f"(instant, no solve) -> replanned phi = {ev['utilization']:.0f} "
      f"({'cache hit' if ev['cache_hit'] else 'engine solve'})")
orch.on_link_degrade({s: 0.5})      # its uplink also drops to half rate
print(f"link {s} at half rate: phi = {orch.program.utilization:.0f}")
orch.on_link_degrade({s: 1.0})
orch.on_switch_recover([s])
print(f"repaired: phi = {orch.program.utilization:.0f}, "
      f"cache stats = {orch.preplan_cache_stats()}")

if args.chaos:
    print()
    print("=" * 64)
    print(f"Phase 5: seeded chaos — {args.chaos} mixed events, invariants "
          f"checked after each (seed {args.seed})")
    print("=" * 64)
    cfg = OrchestratorConfig(k=3, capacity=2, straggler_quantile=0.5)
    # admits=True mixes in multi-job events: device-side hard-admission
    # waves, preemptive admissions, and job releases — the per-switch
    # claim-conservation invariant is checked after each
    events = generate_scenario(topo, n_events=args.chaos, seed=args.seed,
                               cfg=cfg, admits=True)
    orch = Orchestrator(topo, cfg)
    orch.preplan_switch_failures()
    report = ChaosHarness(orch, verify_cache_hits=True).run(events)
    from collections import Counter
    mix = ", ".join(f"{k}x{v}" for k, v in
                    sorted(Counter(e.kind for e in events).items()))
    print(f"events: {mix}")
    print(f"{report.events} events in {report.seconds:.2f}s "
          f"({report.events_per_sec:.0f} ev/s): {report.replans} engine "
          f"solves, {report.cache_hits} preplan-cache hits, "
          f"{report.invariant_checks} invariant checks, all passing")

print()
print("=" * 64)
print("Phase 6: partial capacity — a blue switch loses half its plane")
print("=" * 64)
orch = Orchestrator(topo, OrchestratorConfig(k=3, capacity=2))
s = int(np.nonzero(orch.blue)[0][0])
orch.on_switch_degrade({s: 0.5})
ev = orch.degraded_events[-1]
print(f"switch {s} at 50% capacity: instant degraded phi = "
      f"{ev['degraded_utilization']:.0f} (same blues, overflow spilled "
      f"one hop up) -> replanned phi = {ev['utilization']:.0f}")
orch.on_switch_degrade({s: 1.0})
print(f"plane restored: phi = {orch.program.utilization:.0f} "
      f"({'cache hit' if orch.degraded_events[-1]['cache_hit'] else 'solve'})")

if args.train_chaos:
    import json

    from repro.launch.train import dp_fleet
    from repro.runtime import ChaosTrainer

    print()
    print("=" * 64)
    print(f"Phase 7: training-coupled chaos — {args.train_chaos} events, "
          f"one real optimizer step each (seed {args.seed})")
    print("=" * 64)
    import jax
    topo = dp_fleet(jax.device_count())
    cfg = OrchestratorConfig(k=2)
    events = generate_scenario(topo, n_events=args.train_chaos,
                               seed=args.seed, cfg=cfg, train=True)
    shutil.rmtree(CKPT + "_chaos", ignore_errors=True)
    orch = Orchestrator(topo, cfg)
    trainer = ChaosTrainer(orch, seq=32, global_batch=8,
                           ckpt_dir=CKPT + "_chaos", ckpt_every=5,
                           seed=args.seed)
    report = ChaosHarness(orch, trainer=trainer).run(events)
    tr = report.train
    print(f"{report.events} events / {tr['steps']} steps: "
          f"{tr['bitwise_checks']} lossless recoveries bit-identical to "
          f"the fault-free program, {tr['restores']} checkpoint restarts, "
          f"loss {tr['first_loss']:.3f} -> {tr['last_loss']:.3f}")
    out = os.path.join("experiments", "bench", "chaos_train_report.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        json.dump({"events": report.events, "replans": report.replans,
                   "cache_hits": report.cache_hits,
                   "invariant_checks": report.invariant_checks,
                   "records": report.records, "train": tr}, fh, indent=2,
                  default=float)
    print(f"report -> {out}")
