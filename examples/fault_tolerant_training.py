"""Fault-tolerance walk-through: failures, stragglers, checkpoint resume.

Demonstrates the full runtime story on 8 simulated devices:
  1. train with SOAR-scheduled reduction, checkpointing every 5 steps;
  2. two chips die mid-run -> orchestrator re-sows the blue placement and
     training continues on the survivors;
  3. the process "crashes" (we stop), then resumes exactly from the last
     checkpoint;
  4. a persistent straggler is quarantined by the deadline policy.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
(The script re-executes itself with XLA_FLAGS so the 8 fake devices are
installed before jax initializes.)
"""
import os
import shutil
import subprocess
import sys

FLAG = "--xla_force_host_platform_device_count=8"

if os.environ.get("XLA_FLAGS", "") != FLAG:
    env = {**os.environ, "XLA_FLAGS": FLAG,
           "PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
    sys.exit(subprocess.run([sys.executable, __file__] + sys.argv[1:],
                            env=env).returncode)

import numpy as np  # noqa: E402

from repro.launch import train  # noqa: E402
from repro.runtime import Orchestrator, OrchestratorConfig  # noqa: E402
from repro.collectives import chip_level_tree  # noqa: E402

CKPT = "/tmp/repro_ft_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

print("=" * 64)
print("Phase 1: train 12 steps; chips 3 and 6 fail at steps 5 and 8")
print("=" * 64)
train.main([
    "--arch", "granite-20b", "--reduced", "--steps", "12",
    "--global-batch", "8", "--seq", "64", "--k", "2",
    "--fail", "5:3;8:6", "--ckpt-dir", CKPT, "--ckpt-every", "5",
    "--log-every", "3",
])

print()
print("=" * 64)
print("Phase 2: 'crash' and resume from the latest checkpoint")
print("=" * 64)
train.main([
    "--arch", "granite-20b", "--reduced", "--steps", "18",
    "--global-batch", "8", "--seq", "64", "--k", "2",
    "--ckpt-dir", CKPT, "--resume", "--log-every", "3",
])

print()
print("=" * 64)
print("Phase 3: straggler quarantine (policy demo, no training)")
print("=" * 64)
topo = chip_level_tree(n_pods=2, racks_per_pod=2, chips_per_rack=2)
orch = Orchestrator(topo, OrchestratorConfig(k=2, straggler_patience=2))
print(f"initial phi = {orch.program.utilization:.0f}")
durations = np.ones(8)
durations[5] = 8.0          # device 5 is persistently 8x slower
for step in range(3):
    rep = orch.on_step_durations(durations)
    print(f"step {step}: suspects={np.nonzero(rep.suspects)[0].tolist()} "
          f"quarantined={np.nonzero(rep.quarantined)[0].tolist()}")
print(f"after quarantine: alive={orch.n_alive}, replans={orch.replans}, "
      f"phi={orch.program.utilization:.0f}")
orch.on_recover([5])
print(f"after recovery : alive={orch.n_alive}, "
      f"phi={orch.program.utilization:.0f}")
