"""Multi-tenant NaaS scenario (paper Sec. 5.2): workloads arrive online,
each gets at most k aggregation switches, and every switch has a bounded
aggregation capacity a(s). Compares SOAR against the contending strategies,
shows the capacity-exhaustion effect the paper reports, and demonstrates
the batched placement engine: all tenants planned in ONE level-synchronous
JAX solve (`repro.engine.solve_batch`) instead of a serial per-tenant loop.

Run:  python examples/multi_tenant_placement.py
      (or PYTHONPATH=src python examples/multi_tenant_placement.py from a
       source checkout without `pip install -e .`)
"""
import time

import numpy as np

from repro.core import bt, phi, all_red
from repro.core.online import online_allocate, workload_stream
from repro.engine import solve_batch

N_TOTAL = 256      # BT(256) datacenter tree
K = 16             # per-workload blue budget
CAPACITY = 4       # each switch can serve 4 workloads
N_WORKLOADS = 32

t = bt(N_TOTAL, "linear")
workloads = workload_stream(t, N_WORKLOADS, seed=0)

# ---------------------------------------------------------------------------
# Batched planning pass: every tenant solved at once, capacity-unconstrained.
# This is the engine's bread and butter — one compiled level sweep places
# the whole tenant fleet and prices each tenant's ideal (uncontended) cost.
# ---------------------------------------------------------------------------
solve_batch([t] * N_WORKLOADS, workloads, K)            # warm the jit cache
t0 = time.perf_counter()
batch = solve_batch([t] * N_WORKLOADS, workloads, K)
dt = time.perf_counter() - t0
red = np.asarray([phi(t, L, all_red(t)) for L in workloads])
print(f"BT({N_TOTAL}), linear rates, {N_WORKLOADS} tenants, k={K}, "
      f"capacity={CAPACITY}\n")
print(f"batched engine: {N_WORKLOADS} tenants placed in {dt * 1e3:.1f} ms "
      f"({N_WORKLOADS / dt:.0f} instances/sec)")
print(f"uncontended utilization vs all-red: "
      f"{batch.costs.sum() / red.sum():.4f}\n")

# ---------------------------------------------------------------------------
# Online capacity-constrained admission (the paper's Fig. 7 setting).
# ---------------------------------------------------------------------------
print(f"{'strategy':<10} {'norm. utilization':<18} {'switches exhausted'}")
for strategy in ("soar", "top", "max", "level", "random"):
    res = online_allocate(t, workloads, K, CAPACITY, strategy=strategy)
    exhausted = int((res.residual_capacity == 0).sum())
    print(f"{strategy:<10} {res.normalized[-1]:<18.4f} {exhausted}")

print("\nCapacity pressure (SOAR): cumulative normalized utilization")
res = online_allocate(t, workloads, K, CAPACITY, strategy="soar")
for i in (0, 7, 15, 23, 31):
    print(f"  after workload {i + 1:>2}: {res.normalized[i]:.4f}")
print("\nAs capacity depletes, later workloads find fewer available"
      "\nswitches and the ratio drifts towards all-red (= 1.0) — the"
      "\npaper's Fig. 7 effect. The contention penalty vs the batched"
      "\nuncontended plan above is the price of bounded capacity:"
      f"\n  online {res.normalized[-1]:.4f}  vs  uncontended "
      f"{batch.costs.sum() / red.sum():.4f}")
