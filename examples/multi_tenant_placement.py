"""Multi-tenant NaaS scenario (paper Sec. 5.2): workloads arrive online,
each gets at most k aggregation switches, and every switch has a bounded
aggregation capacity a(s). Compares SOAR against the contending strategies
and shows the capacity-exhaustion effect the paper reports.

Run:  PYTHONPATH=src python examples/multi_tenant_placement.py
"""
import numpy as np

from repro.core import bt
from repro.core.online import online_allocate, workload_stream

N_TOTAL = 256      # BT(256) datacenter tree
K = 16             # per-workload blue budget
CAPACITY = 4       # each switch can serve 4 workloads
N_WORKLOADS = 32

t = bt(N_TOTAL, "linear")
workloads = workload_stream(t, N_WORKLOADS, seed=0)

print(f"BT({N_TOTAL}), linear rates, {N_WORKLOADS} workloads, "
      f"k={K}, capacity={CAPACITY}\n")
print(f"{'strategy':<10} {'norm. utilization':<18} {'switches exhausted'}")
for strategy in ("soar", "top", "max", "level", "random"):
    res = online_allocate(t, workloads, K, CAPACITY, strategy=strategy)
    exhausted = int((res.residual_capacity == 0).sum())
    print(f"{strategy:<10} {res.normalized[-1]:<18.4f} {exhausted}")

print("\nCapacity pressure (SOAR): cumulative normalized utilization")
res = online_allocate(t, workloads, K, CAPACITY, strategy="soar")
for i in (0, 7, 15, 23, 31):
    print(f"  after workload {i + 1:>2}: {res.normalized[i]:.4f}")
print("\nAs capacity depletes, later workloads find fewer available"
      "\nswitches and the ratio drifts towards all-red (= 1.0) — the"
      "\npaper's Fig. 7 effect.")
