"""End-to-end driver: train a ~100M-param qwen3-family model with
SOAR-scheduled data-parallel gradient reduction, checkpointing, and the
synthetic Zipf data pipeline.

Run (full, a few hundred steps — takes a while on CPU):
  PYTHONPATH=src python examples/train_e2e.py

Quick smoke:
  PYTHONPATH=src python examples/train_e2e.py --steps 10 --log-every 2

Multi-device SOAR reduction (8 simulated devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_e2e.py --steps 30
"""
import sys

from repro.launch import train

DEFAULTS = [
    "--arch", "qwen3-32b",
    "--preset-100m",
    "--global-batch", "8",
    "--seq", "256",
    "--k", "2",
    "--ckpt-dir", "/tmp/repro_e2e_ckpt",
    "--ckpt-every", "50",
]

if __name__ == "__main__":
    extra = sys.argv[1:]
    if not any(a == "--steps" for a in extra):
        extra += ["--steps", "300"]
    train.main(DEFAULTS + extra)
