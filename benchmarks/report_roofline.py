"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONs.

Usage: PYTHONPATH=src python -m benchmarks.report_roofline [--dir experiments/dryrun]
Prints markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK = 197e12  # bf16 FLOP/s per chip


def load(dirname: str):
    cells = {}
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(f))
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.1f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def roofline_table(cells, mesh="single"):
    rows = []
    for (arch, shape, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        if d["status"] != "ok":
            rows.append(f"| {arch} | {shape} | — | — | — | — | skip | — | "
                        f"{d['reason'][:46]} |")
            continue
        r = d["roofline"]
        c, me, co = r["compute_s"], r["memory_s"], r["collective_s"]
        dom = r["bottleneck"]
        mf = d["model_flops"]
        n = d["n_devices"]
        ideal = mf / (n * PEAK)
        bound = r["step_time_lower_bound_s"]
        frac = ideal / bound if bound else 0.0
        rows.append(
            f"| {arch} | {shape} | {fmt_s(c)} | {fmt_s(me)} | {fmt_s(co)} | "
            f"{fmt_s(bound)} | {dom} | {d['useful_flops_ratio']:.2f} | "
            f"{100*frac:.1f}% |")
    header = ("| arch | shape | compute_s | memory_s | collective_s | "
              "bound_s | bottleneck | useful_flops | roofline_frac |\n"
              "|---|---|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(rows)


def dryrun_table(cells):
    rows = []
    for (arch, shape, m), d in sorted(cells.items()):
        if d["status"] != "ok":
            rows.append(f"| {arch} | {shape} | {m} | skipped | — | — | — |")
            continue
        ma = d.get("memory_analysis", {})
        arg = ma.get("argument_size_in_bytes", 0) / 1e9
        tmp = ma.get("temp_size_in_bytes", 0) / 1e9
        t = d["times"]
        coll = d["roofline"]["collective_ops"]
        coll_s = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in
                          sorted(coll.items()))
        rows.append(
            f"| {arch} | {shape} | {m} | ok ({t['compile_s']:.0f}s) | "
            f"{arg:.1f} | {tmp:.1f} | {coll_s} |")
    header = ("| arch | shape | mesh | compile | args GB/dev | temp GB/dev | "
              "collectives (op:count) |\n|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(rows)


def summary(cells):
    ok = sum(1 for d in cells.values() if d["status"] == "ok")
    sk = sum(1 for d in cells.values() if d["status"] == "skipped")
    er = len(cells) - ok - sk
    return f"{len(cells)} cells: {ok} ok, {sk} documented skips, {er} errors"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--what", default="all",
                    choices=["all", "roofline", "dryrun", "summary"])
    args = ap.parse_args()
    cells = load(args.dir)
    if args.what in ("all", "summary"):
        print(summary(cells))
    if args.what in ("all", "dryrun"):
        print("\n### Dry-run matrix\n")
        print(dryrun_table(cells))
    if args.what in ("all", "roofline"):
        print(f"\n### Roofline terms ({args.mesh}-pod)\n")
        print(roofline_table(cells, args.mesh))


if __name__ == "__main__":
    main()
