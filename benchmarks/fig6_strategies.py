"""Fig. 6: SOAR vs Top/Max/Level/Random across rate schemes x load dists.

BT(256), k in {1,2,4,8,16,32}, performance normalized to all-red; all-blue
plotted for reference. 10 repetitions per cell (paper Sec. 5).
"""
from __future__ import annotations

import numpy as np

from repro.core import STRATEGIES, all_blue, all_red, bt, phi, sample_load, soar_fast

from .common import fmt_table, write_csv

RATE_SCHEMES = ("constant", "linear", "exponential")
LOADS = ("power-law", "uniform")
KS = (1, 2, 4, 8, 16, 32)
REPS = 10
N_TOTAL = 256
CONTENDERS = ("top", "max", "level", "random")


def run(n_total: int = N_TOTAL, reps: int = REPS, quiet: bool = False):
    rows = []
    for scheme in RATE_SCHEMES:
        t = bt(n_total, scheme)
        for dist in LOADS:
            loads = [sample_load(t, dist, seed=r) for r in range(reps)]
            reds = [phi(t, L, all_red(t)) for L in loads]
            blue_cost = np.mean(
                [phi(t, L, all_blue(t)) / r for L, r in zip(loads, reds)]
            )
            for k in KS:
                perf = {"soar": [], **{c: [] for c in CONTENDERS}}
                for L, red in zip(loads, reds):
                    perf["soar"].append(soar_fast(t, L, k).cost / red)
                    for c in CONTENDERS:
                        m = STRATEGIES[c](t, L, k, seed=17)
                        perf[c].append(phi(t, L, m) / red)
                row = [scheme, dist, k] + [
                    float(np.mean(perf[s])) for s in ("soar",) + CONTENDERS
                ] + [float(blue_cost)]
                rows.append(row)
                # optimality sanity: SOAR beats every contender on average
                for c in CONTENDERS:
                    assert np.mean(perf["soar"]) <= np.mean(perf[c]) + 1e-9, (
                        scheme, dist, k, c)
    header = ["rates", "load", "k", "soar", "top", "max", "level", "random",
              "all_blue"]
    write_csv("fig6_strategies.csv", header, rows)
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
