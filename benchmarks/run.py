"""Benchmark runner: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]`` runs every benchmark,
writes CSVs under experiments/bench/, and prints a per-figure summary.
Each module also asserts the paper's qualitative claims (SOAR optimal /
best-in-class, scaling trends), so a green run doubles as validation.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (admission, beyond_bottleneck, beyond_budget, congestion,
               degraded, engine_throughput, fig6_strategies, fig7_online,
               fig8_usecases, fig9_runtime, fig10_scaling, fig11_scalefree,
               fleet, paper_claims, recovery)

BENCHES = [
    ("paper_claims (Figs 1-3 + brute-force optimality)", paper_claims.run, {}),
    ("fig6_strategies", fig6_strategies.run, {}),
    ("fig7_online", fig7_online.run, {}),
    ("fig8_usecases", fig8_usecases.run, {}),
    ("fig9_runtime", fig9_runtime.run, {}),
    ("fig10_scaling", fig10_scaling.run, {}),
    ("fig11_scalefree", fig11_scalefree.run, {}),
    ("engine_throughput (batched vs serial placement)",
     engine_throughput.run, {}),
    ("congestion (driver vs utilization-only placement)",
     congestion.run, {}),
    ("fleet (coupled multi-tree vs independent per-tree solves)",
     fleet.run, {}),
    ("admission (device-side hard admission vs host claim accounting)",
     admission.run, {}),
    ("beyond_bottleneck (paper §8 conjecture)", beyond_bottleneck.run, {}),
    ("beyond_budget (paper §8 open problem 2)", beyond_budget.run, {}),
    ("recovery (preplan cache + degraded mode + chaos)", recovery.run, {}),
    ("degraded (partial capacity + chaos training)", degraded.run, {}),
]

FAST_OVERRIDES = {
    "fig6_strategies": dict(reps=3),
    "fig7_online": dict(reps=2),
    "fig8_usecases": dict(reps=2),
    "fig9_runtime": dict(reps=1, sizes=(256, 512, 1024), ks=(4, 16, 64),
                         engine_b=8),
    "fig10_scaling": dict(reps=1, sizes=(256, 512, 1024)),
    "fig11_scalefree": dict(reps=2, sizes=(256, 512, 1024)),
    "engine_throughput": dict(reps=2, batches=(8, 64)),
    "congestion (": dict(tenants=(8,), max_rounds=4, reps=1),
    "fleet (": dict(tenants=(8,), max_rounds=4, reps=1),
    "admission (": dict(tenants=(16,), reps=1),
    "recovery (": dict(n_pods=2, racks=2, events=30),
    "degraded (": dict(n_pods=2, racks=2, events=25, seq=16),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced reps/sizes for CI-style runs")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name")
    args = ap.parse_args(argv)

    t_all = time.perf_counter()
    for name, fn, kw in BENCHES:
        if args.only and args.only not in name:
            continue
        if args.fast:
            for key, ov in FAST_OVERRIDES.items():
                if key in name:
                    kw = {**kw, **ov}
        print(f"\n=== {name} ===", flush=True)
        t0 = time.perf_counter()
        fn(**kw)
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]", flush=True)
    print(f"\nAll benchmarks done in {time.perf_counter() - t_all:.1f}s; "
          f"CSVs in experiments/bench/")


if __name__ == "__main__":
    main()
