"""Device-side hard admission vs host-side claim accounting.

Multi-workload admission under bounded per-switch capacity (paper
Sec. 5.2) done two ways on the same orchestrator state:

  * host    — the wave is congestion-solved *unconstrained*, then claims
    apply serially on the host ledger; every placement that lands on an
    exhausted switch pays one extra solve round trip (the collision
    fallback), so the bill grows with contention;
  * device  — the orchestrator's residual ledger rides into the penalty
    loop as the engine's ``residual=`` constraint and admission happens
    *inside* the jitted ``lax.while_loop``: the returned wave is feasible
    wholesale, claims apply with zero collisions and one solve total.

Emits ``BENCH_admission.json`` plus a CSV. At every scenario with
T >= ASSERT_MIN_T tenants, asserts the acceptance bar for the in-loop
admission work: the host path pays at least ``MIN_RT_RATIO`` (2x) more
host<->device admission round trips than the device path, the device
wave needs zero post-hoc evictions/collisions while the host path hits
at least one collision, and the device-admitted masks are bit-identical
to the engine's host-ledger reference (``device_loop=False`` replay of
the same residual ledger — the differential contract the test suite
checks in miniature).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.collectives import fleet_tree
from repro.engine import solve_congestion
from repro.runtime import Orchestrator, OrchestratorConfig, PreemptionPolicy

from .common import fmt_table, out_path, write_csv

N_PODS = 2
RACKS = 4
CHIPS = 4
K = 4
CAPACITY = 2
MAX_ROUNDS = 2
TENANTS = (8, 16)
REPS = 2
MIN_RT_RATIO = 2.0        # acceptance: >= 2x fewer admission round trips
ASSERT_MIN_T = 16         # ... asserted from this wave size up


def _orch(n_pods: int, racks: int, chips: int, k: int, capacity: int):
    topo = fleet_tree(n_pods=n_pods, racks_per_pod=racks,
                      chips_per_rack=chips)
    return Orchestrator(topo, OrchestratorConfig(k=k, capacity=capacity))


def run(tenants=TENANTS, k: int = K, capacity: int = CAPACITY,
        n_pods: int = N_PODS, racks: int = RACKS, chips: int = CHIPS,
        max_rounds: int = MAX_ROUNDS, reps: int = REPS,
        quiet: bool = False):
    rows = []
    bench: list[dict] = []
    # warm the solve shapes out of band (jit compile is not the story)
    warm = _orch(n_pods, racks, chips, k, capacity)
    warm.begin_workloads(int(tenants[0]), congestion_aware=True,
                         device_admission=True, max_rounds=max_rounds)
    for T in tenants:
        T = int(T)
        t_host, host = np.inf, None
        for _ in range(reps):
            o = _orch(n_pods, racks, chips, k, capacity)
            t0 = time.perf_counter()
            o.begin_workloads(T, congestion_aware=True,
                              max_rounds=max_rounds)
            t_host = min(t_host, time.perf_counter() - t0)
            host = o
        t_dev, dev = np.inf, None
        for _ in range(reps):
            o = _orch(n_pods, racks, chips, k, capacity)
            residual0 = o._residual.copy()
            avail0 = o._avail()
            t0 = time.perf_counter()
            o.begin_workloads(T, congestion_aware=True,
                              device_admission=True, max_rounds=max_rounds)
            t_dev = min(t_dev, time.perf_counter() - t0)
            dev = o
        h, d = host.last_admission, dev.last_admission
        ratio = h["round_trips"] / max(d["round_trips"], 1)

        # differential contract: the device-admitted masks are the
        # host-ledger engine reference's, bit for bit
        ref = solve_congestion(
            dev.topo.tree, [dev.topo.load] * T, k, avail=[avail0] * T,
            residual=residual0, device_loop=False, max_rounds=max_rounds)
        admitted = np.stack(
            [j.blue for j in sorted(dev.jobs.values(),
                                    key=lambda j: j.order)])
        bit_identical = bool(np.array_equal(admitted, ref.blue))

        row = dict(
            T=T, k=k, capacity=capacity,
            rt_host=h["round_trips"], rt_device=d["round_trips"],
            rt_ratio=ratio,
            collisions_host=h["collisions"],
            collisions_device=d["collisions"],
            dropped_device=d["dropped"],
            bit_identical=bit_identical,
            admit_s_host=t_host, admit_s_device=t_dev,
        )
        bench.append(row)
        rows.append(list(row.values()))
        assert bit_identical, (
            f"device-admitted masks diverged from the host-ledger "
            f"reference at T={T}")
        assert d["collisions"] == 0 and (dev._residual >= 0).all(), (
            f"device admission needed post-hoc fixups at T={T}")
        if T >= ASSERT_MIN_T:
            assert h["collisions"] >= 1, (
                f"host path saw no collisions at T={T} — scenario too "
                f"easy to measure the round-trip gap")
            assert ratio >= MIN_RT_RATIO, (
                f"device admission saved only {ratio:.1f}x round trips at "
                f"T={T} — below the {MIN_RT_RATIO:.0f}x bar "
                f"({h['round_trips']} host vs {d['round_trips']} device)")

    # one preemptive wave for the record: scarce ledger, policy evicts,
    # single re-solve (two round trips total, still no collisions)
    o = _orch(n_pods, racks, chips, k, capacity)
    for _ in range(3):
        o.begin_workload(priority=1)
    o.begin_workloads(int(tenants[-1]), congestion_aware=True,
                      device_admission=True,
                      preemption=PreemptionPolicy("priority"),
                      max_rounds=max_rounds)
    pre = o.last_admission
    assert pre["solves"] <= 2 and pre["collisions"] == 0
    assert (o._residual >= 0).all()

    header = list(bench[0].keys())
    write_csv("admission.csv", header, rows)
    with open(out_path("BENCH_admission.json"), "w") as fh:
        json.dump({"n_pods": n_pods, "racks": racks, "chips": chips,
                   "k": k, "capacity": capacity, "max_rounds": max_rounds,
                   "min_rt_ratio": MIN_RT_RATIO,
                   "preemption": {"solves": pre["solves"],
                                  "preempted": len(pre["preempted"]),
                                  "dropped": pre["dropped"]},
                   "rows": bench}, fh, indent=2)
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
