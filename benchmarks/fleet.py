"""Fleet-coupled placement vs independent per-tree solves.

The multi-tree setting: two aggregation trees hang off one shared core
spine, and every tenant's root-crossing messages transit it — the link
where tenants on *different* trees contend. We place each scenario's
tenants (split evenly across the trees) two ways:

  * independent — one ``solve_congestion`` per tree, the pre-fleet
    serving pattern: each tree's tenants are congestion-balanced on
    their own tree, but the solve is blind to the shared core;
  * coupled     — one ``solve_fleet`` over the whole fleet: the penalty
    loop profiles the union of tree-local and shared-core links, and
    the DP sees the core transit cost on every root-crossing message,
    so tenants aggregate root-side to shed core traffic.

Both placements are measured with ``measure_fleet_multi`` on the fleet's
global link-id space (tree segments first, core links last), so the
shared-core comparison is apples to apples. Emits ``BENCH_fleet.json``
plus a CSV; at every scenario with T >= ASSERT_MIN_T total tenants,
asserts the coupled solve cuts the shared-core max-link congestion by at
least ``MIN_CORE_REDUCTION`` (15%) vs the independent solves — the
acceptance bar for the fleet work — and that an N=1 fleet solve stays
bit-identical to ``solve_congestion`` (the degeneracy contract).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.collectives import build_fleet
from repro.core.congestion import measure_fleet_multi
from repro.core.tree import sample_load
from repro.engine import solve_congestion, solve_fleet

from .common import fmt_table, out_path, write_csv

N_TREES = 2
N_PODS = 2
RACKS = 4
CHIPS = 4
SPINE_RHO = 64.0          # shared core is the expensive hop (DCN spine)
K = 4
T = 16                    # total tenants, split evenly across the trees
MAX_ROUNDS = 8
REPS = 2
MIN_CORE_REDUCTION = 0.15  # acceptance: >= 15% lower shared-core max link
ASSERT_MIN_T = 8           # ... asserted from the CI smoke scenario up


def _check_n1_degeneracy(fleet, k: int, max_rounds: int) -> None:
    """A 1-tree fleet must be solve_congestion, bit for bit."""
    tree = fleet.topos[0].tree
    loads = [sample_load(tree, "power-law", seed=900 + s) for s in range(4)]
    single = solve_congestion(tree, loads, k, max_rounds=max_rounds,
                              record_rounds=True)
    one = solve_fleet([tree], loads, [0] * 4, k, max_rounds=max_rounds,
                      record_rounds=True)
    assert one.history == single.history, "N=1 fleet history diverged"
    assert np.array_equal(one.blue, single.blue), "N=1 fleet masks diverged"
    assert np.array_equal(one.congestion, single.congestion)
    for (oe, ob), (se, sb) in zip(one.rounds_log, single.rounds_log,
                                  strict=True):
        assert np.array_equal(oe, se) and np.array_equal(ob, sb), \
            "N=1 fleet round log diverged"


def run(tenants=(T,), k: int = K, n_pods: int = N_PODS, racks: int = RACKS,
        chips: int = CHIPS, spine_rho: float = SPINE_RHO,
        max_rounds: int = MAX_ROUNDS, reps: int = REPS,
        quiet: bool = False):
    fleet = build_fleet(N_TREES, n_pods, racks, chips, spine_rho=spine_rho)
    trees = [tp.tree for tp in fleet.topos]
    _check_n1_degeneracy(fleet, min(k, 2), min(max_rounds, 3))
    rows = []
    bench: list[dict] = []
    for T_i in tenants:
        per_tree = max(1, T_i // N_TREES)
        T_i = per_tree * N_TREES
        tree_of = [g for g in range(N_TREES) for _ in range(per_tree)]
        loads = [sample_load(trees[g], "power-law", seed=17 * t + g)
                 for t, g in enumerate(tree_of)]

        # warm both solve shapes before timing (jit compile out of band)
        solve_fleet(trees, loads, tree_of, k, core_rho=fleet.core_rho,
                    core_path=fleet.core_path, max_rounds=max_rounds)
        for g in range(N_TREES):
            rows_g = [t for t in range(T_i) if tree_of[t] == g]
            solve_congestion(trees[g], [loads[t] for t in rows_g], k,
                             max_rounds=max_rounds)

        t_cpl, res = np.inf, None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = solve_fleet(trees, loads, tree_of, k,
                            core_rho=fleet.core_rho,
                            core_path=fleet.core_path,
                            max_rounds=max_rounds)
            t_cpl = min(t_cpl, time.perf_counter() - t0)
            res = r
        t_ind, indep_blues = np.inf, None
        for _ in range(reps):
            t0 = time.perf_counter()
            blues = []
            for g in range(N_TREES):
                rows_g = [t for t in range(T_i) if tree_of[t] == g]
                rg = solve_congestion(trees[g],
                                      [loads[t] for t in rows_g], k,
                                      max_rounds=max_rounds)
                blues.extend(np.asarray(rg.blue[i])
                             for i in range(len(rows_g)))
            t_ind = min(t_ind, time.perf_counter() - t0)
            indep_blues = blues

        kw = dict(core_rho=fleet.core_rho, core_path=fleet.core_path)
        n0 = trees[0].n
        cpl_blues = [np.asarray(res.blue[t, : trees[g].n])
                     for t, g in enumerate(tree_of)]
        m_cpl = measure_fleet_multi(trees, tree_of, loads, cpl_blues, **kw)
        m_ind = measure_fleet_multi(trees, tree_of, loads, indep_blues, **kw)
        core_cpl = float(m_cpl.core_congestion.max())
        core_ind = float(m_ind.core_congestion.max())
        core_reduction = 1.0 - core_cpl / max(core_ind, 1e-12)
        row = dict(
            T=T_i,
            per_tree=per_tree,
            k=k,
            spine_rho=spine_rho,
            core_indep=core_ind,
            core_coupled=core_cpl,
            core_reduction=core_reduction,
            global_max_indep=m_ind.max_congestion,
            global_max_coupled=m_cpl.max_congestion,
            rounds=res.rounds,
            best_round=res.best_round,
            solve_s_coupled=t_cpl,
            solve_s_indep=t_ind,
        )
        bench.append(row)
        rows.append(list(row.values()))
        if T_i >= ASSERT_MIN_T:
            assert core_reduction >= MIN_CORE_REDUCTION, (
                f"fleet-coupled solve cut shared-core max congestion by "
                f"only {100 * core_reduction:.1f}% at T={T_i} — below the "
                f"{100 * MIN_CORE_REDUCTION:.0f}% bar "
                f"(core {core_ind:.1f} -> {core_cpl:.1f})")
    header = list(bench[0].keys())
    write_csv("fleet.csv", header, rows)
    with open(out_path("BENCH_fleet.json"), "w") as fh:
        json.dump({"n_trees": N_TREES, "n_pods": n_pods, "racks": racks,
                   "chips": chips, "k": k, "spine_rho": spine_rho,
                   "max_rounds": max_rounds,
                   "min_core_reduction": MIN_CORE_REDUCTION, "rows": bench},
                  fh, indent=2)
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tenants", type=str, default=str(T),
                    help="comma-separated total tenant counts, split "
                         "evenly across the 2 trees (the >=15%% "
                         "shared-core reduction asserts from T >= "
                         f"{ASSERT_MIN_T} up)")
    ap.add_argument("--k", type=int, default=K)
    ap.add_argument("--pods", type=int, default=N_PODS)
    ap.add_argument("--racks", type=int, default=RACKS)
    ap.add_argument("--chips", type=int, default=CHIPS)
    ap.add_argument("--spine-rho", type=float, default=SPINE_RHO)
    ap.add_argument("--rounds", type=int, default=MAX_ROUNDS)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args(argv)
    run(tenants=tuple(int(x) for x in args.tenants.split(",")),
        k=args.k, n_pods=args.pods, racks=args.racks, chips=args.chips,
        spine_rho=args.spine_rho, max_rounds=args.rounds, reps=args.reps)


if __name__ == "__main__":
    main()
