"""Fig. 7: online multi-workload allocation under per-switch capacity.

Baseline: BT(256), k=16, a(s)=4, 32 workloads; rate schemes constant /
linear / exponential. Top plots sweep #workloads at capacity 4; bottom
plots sweep capacity at 32 workloads. Normalized to all-red.
"""
from __future__ import annotations

import numpy as np

from repro.core import bt
from repro.core.online import online_allocate, workload_stream

from .common import fmt_table, write_csv

RATE_SCHEMES = ("constant", "linear", "exponential")
STRATS = ("soar", "top", "max", "level", "random")
N_TOTAL = 256
K = 16
REPS = 5


def run(n_total: int = N_TOTAL, reps: int = REPS, quiet: bool = False):
    rows = []
    # sweep #workloads at capacity 4, and capacity at 32 workloads
    sweeps = [("n_workloads", w, 4) for w in (8, 16, 32, 64)] + [
        ("capacity", 32, c) for c in (1, 2, 4, 8)
    ]
    for scheme in RATE_SCHEMES:
        t = bt(n_total, scheme)
        for sweep, n_w, cap in sweeps:
            for strat in STRATS:
                ratios = []
                for r in range(reps):
                    ws = workload_stream(t, n_w, seed=1000 + r)
                    res = online_allocate(t, ws, K, cap, strategy=strat,
                                          seed=55 + r)
                    ratios.append(float(res.normalized[-1]))
                rows.append([scheme, sweep, n_w, cap, strat,
                             float(np.mean(ratios))])
    header = ["rates", "sweep", "n_workloads", "capacity", "strategy",
              "norm_util"]
    write_csv("fig7_online.csv", header, rows)
    # SOAR should be best (or tied) in every scenario on average
    import collections
    best = collections.defaultdict(dict)
    for scheme, sweep, n_w, cap, strat, v in rows:
        best[(scheme, sweep, n_w, cap)][strat] = v
    for key, d in best.items():
        assert d["soar"] <= min(d.values()) + 1e-9, (key, d)
    if not quiet:
        print(fmt_table(header, rows, max_rows=30))
    return header, rows


if __name__ == "__main__":
    run()
