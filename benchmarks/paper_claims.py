"""Paper-claims validation table: exact worked examples (Figs. 1-3) plus
optimality spot-checks vs brute force. This is the 'faithful reproduction'
gate that EXPERIMENTS.md §Paper-claims reads from.
"""
from __future__ import annotations

import numpy as np

from repro.core import baselines, brute_force
from repro.core.reduce import all_blue, all_red, phi
from repro.core.soar import soar
from repro.core.soar_fast import soar_fast
from repro.core.tree import DEST, Tree, bt, random_tree, sample_load

from .common import fmt_table, write_csv


def _fig2():
    parent = np.array([DEST, 0, 0, 1, 1, 2, 2])
    t = Tree(parent, np.ones(7))
    load = np.zeros(7, dtype=np.int64)
    load[[3, 4, 5, 6]] = [2, 6, 5, 4]
    return t, load


def run(quiet: bool = False):
    rows = []
    t, load = _fig2()
    checks = [
        ("fig2 Top k=2", phi(t, load, baselines.top(t, load, 2)), 27),
        ("fig2 Max k=2", phi(t, load, baselines.max_load(t, load, 2)), 24),
        ("fig2 Level k=2", phi(t, load, baselines.level(t, load, 2)), 21),
        ("fig2 SOAR k=2", soar(t, load, 2).cost, 20),
        ("fig3 SOAR k=1", soar(t, load, 1).cost, 35),
        ("fig3 SOAR k=3", soar(t, load, 3).cost, 15),
        ("fig3 SOAR k=4", soar(t, load, 4).cost, 11),
        ("fig2 all-red", phi(t, load, all_red(t)), 51),
        ("fig2 all-blue", phi(t, load, all_blue(t)), 7),
    ]
    for name, got, want in checks:
        rows.append([name, float(got), float(want),
                     "PASS" if abs(got - want) < 1e-9 else "FAIL"])

    # optimality vs brute force on random instances (Theorem 4.1)
    rng = np.random.default_rng(7)
    for i in range(8):
        n = int(rng.integers(5, 12))
        t = random_tree(n, seed=i)
        L = rng.integers(0, 7, size=n)
        k = int(rng.integers(0, n))
        _, opt = brute_force(t, L, k)
        got = soar(t, L, k).cost
        gotf = soar_fast(t, L, k).cost
        ok = abs(got - opt) < 1e-9 and abs(gotf - opt) < 1e-9
        rows.append([f"brute n={n} k={k} seed={i}", float(got), float(opt),
                     "PASS" if ok else "FAIL"])

    # BT(256) per Sec. 5: SOAR <= every contender under every scheme
    for scheme in ("constant", "linear", "exponential"):
        t = bt(256, scheme)
        L = sample_load(t, "power-law", seed=3)
        red = phi(t, L, all_red(t))
        s = soar_fast(t, L, 16).cost
        worst = max(phi(t, L, fn(t, L, 16, seed=1))
                    for fn in baselines.STRATEGIES.values())
        rows.append([f"BT256 {scheme} SOAR<=contenders", float(s),
                     float(worst), "PASS" if s <= worst + 1e-9 else "FAIL"])

    header = ["claim", "got", "expected/bound", "status"]
    write_csv("paper_claims.csv", header, rows)
    assert all(r[3] == "PASS" for r in rows), [r for r in rows if r[3] != "PASS"]
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
