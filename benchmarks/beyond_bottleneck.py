"""Beyond-paper: testing the paper's §8 conjecture.

"We conjecture ... that of minimizing the overall utilization complexity,
and that of minimizing the overall system delay or bottlenecks, are
closely related, and a solution minimizing one of these objectives is
expected to perform well also for the other."

We solve both objectives exactly — phi (SOAR) and lambda (our
Pareto-frontier bottleneck DP, core/bottleneck.py) — and report, per
scenario, the cross-objective regret:

  phi-regret of lambda*-placement  = phi(U_lambda) / phi(U_phi)
  lambda-regret of phi*-placement  = lambda(U_phi) / lambda(U_lambda)
"""
from __future__ import annotations

import numpy as np

from repro.core import all_red, bt, phi, sample_load, soar_fast
from repro.core.bottleneck import bottleneck_phi, solve_bottleneck

from .common import fmt_table, write_csv

SCENARIOS = [(64, "constant"), (64, "exponential"), (128, "constant"),
             (128, "linear")]
KS = (2, 4, 8)
REPS = 5


def run(scenarios=SCENARIOS, ks=KS, reps: int = REPS, quiet: bool = False):
    rows = []
    for n, scheme in scenarios:
        t = bt(n, scheme)
        for dist in ("power-law", "uniform"):
            for k in ks:
                lam_regret, phi_regret = [], []
                for r in range(reps):
                    L = sample_load(t, dist, seed=100 + r)
                    u_phi = soar_fast(t, L, k).blue
                    u_lam, lam_opt = solve_bottleneck(t, L, k)
                    phi_opt = phi(t, L, u_phi)
                    lam_regret.append(
                        bottleneck_phi(t, L, u_phi) / lam_opt)
                    phi_regret.append(phi(t, L, u_lam) / phi_opt)
                rows.append([n, scheme, dist, k,
                             float(np.mean(lam_regret)),
                             float(np.max(lam_regret)),
                             float(np.mean(phi_regret))])
    header = ["n", "rates", "load", "k", "lam_regret_of_phi*",
              "lam_regret_max", "phi_regret_of_lam*"]
    write_csv("beyond_bottleneck.csv", header, rows)
    # conjecture quantified: regrets should be small (< 2x mean)
    assert all(r[4] < 2.5 and r[6] < 2.5 for r in rows), rows
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
