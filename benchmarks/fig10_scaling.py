"""Fig. 10 / Appendix A: scaling laws of SOAR on growing binary trees.

(a) normalized utilization vs all-red for k = 1%n, log2(n), sqrt(n);
(b) fraction of blue nodes needed for 30/50/70% cost reduction.
Power-law loads, constant rates, n = 2^8 .. 2^12.

Part (a) routes all load repetitions of an (n, k) cell through the batched
engine in one solve (costs-only mode: the ratio needs no coloring); the
adaptive budget search of part (b) stays on the serial solver.
"""
from __future__ import annotations

import numpy as np

from repro.core import all_red, bt, phi, sample_load, soar_fast
from repro.core.forest import build_forest
from repro.engine import EngineOptions, solve_forest

from .common import fmt_table, write_csv

SIZES = (256, 512, 1024, 2048, 4096)
REPS = 3
TARGETS = (0.30, 0.50, 0.70)


def _k_rules(n: int) -> dict[str, int]:
    return {"1%n": max(1, round(0.01 * n)),
            "log n": max(1, round(np.log2(n))),
            "sqrt n": max(1, round(np.sqrt(n)))}


def run(sizes=SIZES, reps: int = REPS, quiet: bool = False):
    rows_a, rows_b = [], []
    for n in sizes:
        t = bt(n, "constant")
        loads = [sample_load(t, "power-law", seed=r) for r in range(reps)]
        reds = [phi(t, L, all_red(t)) for L in loads]
        forest = build_forest([t] * len(loads), loads)   # pack once per n
        for rule, k in _k_rules(n).items():
            costs = solve_forest(forest, k,
                                 options=EngineOptions(color=False)).costs
            ratio = float(np.mean([c / r for c, r in zip(costs, reds)]))
            rows_a.append([n, rule, k, ratio])
        # (b): smallest k achieving each target reduction. SOAR cost is
        # monotone non-increasing in k; exponential search keeps the probe
        # budgets near the answer (k^2 DP cost makes large probes expensive).
        for tgt in TARGETS:
            ks = []
            for L, r in zip(loads, reds):
                hi = 1
                while soar_fast(t, L, hi).cost / r > 1.0 - tgt:
                    hi *= 2
                lo = hi // 2 + 1 if hi > 1 else 0
                while lo < hi:
                    mid = (lo + hi) // 2
                    if soar_fast(t, L, mid).cost / r <= 1.0 - tgt:
                        hi = mid
                    else:
                        lo = mid + 1
                ks.append(lo if hi > 1 else 1)
            rows_b.append([n, f"{int(tgt*100)}%", float(np.mean(ks)),
                           float(np.mean(ks)) / t.n * 100.0])
    write_csv("fig10a_scaling.csv", ["n", "rule", "k", "util_vs_red"], rows_a)
    write_csv("fig10b_budget_for_target.csv",
              ["n", "target_reduction", "k_needed", "pct_of_nodes"], rows_b)
    # paper claim: larger networks need a smaller *fraction* for any target
    by_tgt: dict[str, list] = {}
    for n, tgt, k, pct in rows_b:
        by_tgt.setdefault(tgt, []).append(pct)
    for tgt, pcts in by_tgt.items():
        assert pcts[-1] <= pcts[0] + 1e-9, (tgt, pcts)
    if not quiet:
        print(fmt_table(["n", "rule", "k", "util_vs_red"], rows_a, 99))
        print()
        print(fmt_table(["n", "target", "k_needed", "pct_of_nodes"], rows_b, 99))
    return rows_a, rows_b


if __name__ == "__main__":
    run()
