"""Recovery-path benchmark: preplan cache vs engine solves, degraded mode.

Three measurements on the fault-tolerant orchestrator:

  * **preplanned switch-failure recovery** — preplan every single-switch
    failure (:meth:`Orchestrator.preplan_switch_failures`), then fail and
    repair each switch in turn, against a control orchestrator with no
    preplanning. Reports the fraction of recoveries the cache served
    without an engine solve and the cached vs solved recovery latency;
  * **degraded-mode premium** — for every failure of a *blue* switch, the
    utilization regression of the immediate no-solve degraded program over
    the subsequently replanned one (how much utilization the instant
    fallback costs while the replan lands);
  * **chaos throughput** — a seeded mixed scenario (default 50 events)
    through :class:`ChaosHarness` with every invariant checked per event,
    reported as events/sec.

Emits ``BENCH_recovery.json`` + a CSV. Asserts the acceptance bars: the
preplan cache serves at least ``MIN_HIT_RATE`` (50%) of single-switch
recoveries without a solve, and the chaos scenario completes with all
invariant checks passing (the harness raises otherwise).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.collectives import fleet_tree
from repro.runtime import (ChaosHarness, Orchestrator, OrchestratorConfig,
                           generate_scenario)

from .common import fmt_table, out_path, write_csv

N_PODS = 4
RACKS = 4
CHIPS = 4
K = 6
CAPACITY = 2
EVENTS = 50
SEED = 0
MIN_HIT_RATE = 0.5    # acceptance: cache serves >= 50% of switch recoveries


def _bench_preplanned_switch_recovery(topo, cfg):
    """Fail+repair every switch once, preplanned vs control."""
    orch = Orchestrator(topo, cfg)
    t0 = time.perf_counter()
    orch.preplan_switch_failures()
    preplan_s = time.perf_counter() - t0
    control = Orchestrator(topo, cfg)

    rows, hit_lat, miss_lat = [], [], []
    for s in range(topo.tree.n):
        hits0 = orch.preplan_cache_stats()["hits"]
        t0 = time.perf_counter()
        orch.on_switch_failure([s])
        dt = time.perf_counter() - t0
        hit = orch.preplan_cache_stats()["hits"] > hits0
        (hit_lat if hit else miss_lat).append(dt)

        t0 = time.perf_counter()
        control.on_switch_failure([s])
        control_dt = time.perf_counter() - t0
        assert control.program.utilization == orch.program.utilization

        rows.append([s, int(hit), dt * 1e3, control_dt * 1e3])
        orch.on_switch_recover([s])
        control.on_switch_recover([s])

    n = topo.tree.n
    hit_rate = len(hit_lat) / n
    return {
        "switches": n,
        "preplan_seconds": preplan_s,
        "hit_rate": hit_rate,
        "replans_avoided": len(hit_lat),
        "cached_recovery_ms": float(np.mean(hit_lat)) * 1e3 if hit_lat
        else None,
        "solved_recovery_ms": float(np.mean(miss_lat)) * 1e3 if miss_lat
        else None,
        "control_replans": control.replans,
        "preplanned_replans": orch.replans,
    }, rows


def _bench_degraded_premium(topo, cfg):
    """Fail each initially-blue switch; measure the degraded-mode premium."""
    premiums = []
    for s in np.nonzero(Orchestrator(topo, cfg).blue)[0]:
        orch = Orchestrator(topo, cfg)
        orch.on_switch_failure([int(s)])
        ev = orch.degraded_events[-1]
        premiums.append(ev["degraded_utilization"] / ev["utilization"] - 1.0)
    return {
        "blue_switches": len(premiums),
        "mean_premium": float(np.mean(premiums)) if premiums else 0.0,
        "max_premium": float(np.max(premiums)) if premiums else 0.0,
    }


def _bench_chaos(topo, cfg, events, seed):
    scenario = generate_scenario(topo, n_events=events, seed=seed, cfg=cfg)
    orch = Orchestrator(topo, cfg)
    orch.preplan_switch_failures()
    report = ChaosHarness(orch, verify_cache_hits=True).run(scenario)
    return {
        "events": report.events,
        "replans": report.replans,
        "cache_hits": report.cache_hits,
        "stale": report.stale,
        "invariant_checks": report.invariant_checks,
        "seconds": report.seconds,
        "events_per_sec": report.events_per_sec,
    }


def run(n_pods: int = N_PODS, racks: int = RACKS, chips: int = CHIPS,
        k: int = K, capacity: int = CAPACITY, events: int = EVENTS,
        seed: int = SEED, quiet: bool = False):
    topo = fleet_tree(n_pods, racks, chips)
    cfg = OrchestratorConfig(k=k, capacity=capacity)

    switch, rows = _bench_preplanned_switch_recovery(topo, cfg)
    degraded = _bench_degraded_premium(topo, cfg)
    chaos = _bench_chaos(topo, cfg, events, seed)

    write_csv("BENCH_recovery.csv",
              ["switch", "cache_hit", "recovery_ms", "control_ms"], rows)
    payload = {
        "n_pods": n_pods, "racks_per_pod": racks, "chips_per_rack": chips,
        "k": k, "capacity": capacity, "chaos_events": events, "seed": seed,
        "switch_recovery": switch,
        "degraded_mode": degraded,
        "chaos": chaos,
    }
    with open(out_path("BENCH_recovery.json"), "w") as fh:
        json.dump(payload, fh, indent=2)

    if not quiet:
        print(fmt_table(["switch", "hit", "ms", "control_ms"], rows,
                        max_rows=12))
        print(f"\npreplan: {switch['switches']} scenarios in "
              f"{switch['preplan_seconds']:.2f}s (one batched solve)")
        print(f"cache hit rate: {switch['hit_rate']:.0%} "
              f"({switch['replans_avoided']}/{switch['switches']} recoveries "
              f"without a solve)")
        if switch["cached_recovery_ms"] is not None:
            line = f"cached recovery: {switch['cached_recovery_ms']:.2f}ms"
            if switch["solved_recovery_ms"] is not None:
                line += f" vs solved {switch['solved_recovery_ms']:.2f}ms"
            print(line)
        print(f"degraded-mode premium over replanned: "
              f"mean {degraded['mean_premium']:.1%}, "
              f"max {degraded['max_premium']:.1%} "
              f"({degraded['blue_switches']} blue switches)")
        print(f"chaos: {chaos['events']} events, {chaos['replans']} solves, "
              f"{chaos['cache_hits']} cache hits, "
              f"{chaos['invariant_checks']} invariant checks, "
              f"{chaos['events_per_sec']:.0f} events/s")

    assert switch["hit_rate"] >= MIN_HIT_RATE, (
        f"preplan cache served {switch['hit_rate']:.0%} of single-switch "
        f"recoveries, need >= {MIN_HIT_RATE:.0%}")
    assert chaos["invariant_checks"] == events
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--pods", type=int, default=N_PODS)
    ap.add_argument("--k", type=int, default=K)
    args = ap.parse_args(argv)
    run(n_pods=args.pods, k=args.k, events=args.events, seed=args.seed)


if __name__ == "__main__":
    main()
