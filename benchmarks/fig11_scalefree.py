"""Fig. 11 / Appendix B: SOAR on scale-free (preferential-attachment) trees.

Load 1 at every switch (paper's unbiased setting). (a/b) SOAR vs Max-degree
at SF(128), k=4 — the paper reports 182 vs 621 on its sampled instance;
(c) scaling for k = 1%n, log2 n, sqrt n over n = 2^8..2^12.
"""
from __future__ import annotations

import numpy as np

from repro.core import all_red, bt, max_degree, phi, rpa, soar_fast
from repro.core.tree import sample_load

from .common import fmt_table, write_csv

SIZES = (256, 512, 1024, 2048, 4096)
REPS = 5


def run(sizes=SIZES, reps: int = REPS, quiet: bool = False):
    # (a/b) SF(128), k=4: SOAR strictly beats Max-degree
    rows_ab = []
    for seed in range(reps):
        t = rpa(128, seed=seed)
        L = sample_load(t, "ones", leaves_only=False)
        soar_cost = soar_fast(t, L, 4).cost
        maxd_cost = phi(t, L, max_degree(t, L, 4))
        rows_ab.append([seed, soar_cost, maxd_cost, soar_cost / maxd_cost])
        assert soar_cost <= maxd_cost + 1e-9
    write_csv("fig11ab_sf128.csv",
              ["seed", "soar_cost", "max_degree_cost", "ratio"], rows_ab)

    # (c) scaling
    rows_c = []
    for n in sizes:
        for rule, k in {"1%n": max(1, round(0.01 * n)),
                        "log n": max(1, round(np.log2(n))),
                        "sqrt n": max(1, round(np.sqrt(n)))}.items():
            ratios = []
            for seed in range(reps):
                t = rpa(n, seed=seed)
                L = sample_load(t, "ones", leaves_only=False)
                red = phi(t, L, all_red(t))
                ratios.append(soar_fast(t, L, k).cost / red)
            rows_c.append([n, rule, k, float(np.mean(ratios))])
    write_csv("fig11c_sf_scaling.csv", ["n", "rule", "k", "util_vs_red"],
              rows_c)
    if not quiet:
        print(fmt_table(["seed", "soar", "max_degree", "ratio"], rows_ab, 99))
        print()
        print(fmt_table(["n", "rule", "k", "util_vs_red"], rows_c, 99))
    return rows_ab, rows_c


if __name__ == "__main__":
    run()
