"""Congestion driver vs utilization-only placement; device vs host loop.

The fleet multi-tenant scenario (paper Sec. 5.2 workload shape): T tenants
share one datacenter reduction tree, each with its own power-law load. We
place the fleet two ways and compare the *max-link congestion* (Segal et
al. 2022 objective — the hottest link's total message count across
tenants):

  * ``solve_batch``       — utilization-only: every tenant individually
                            optimal, one device-resident engine solve;
  * ``solve_congestion``  — the repeated-solve penalty driver: re-solves
                            the batch under reweighted link rates until the
                            hottest link stops improving (monotone-best).

The driver itself is timed both ways it can run:

  * ``device_loop=True``  — the whole round loop as one jitted
                            ``lax.while_loop`` on the accelerator; only the
                            best masks + scalar history transfer at the end;
  * ``device_loop=False`` — the host-driven reference (PR 3's serving
                            pattern: per-round Forest re-pack, re-upload,
                            and mask/count/C_max pullback), bit-identical
                            round for round.

Emits ``BENCH_congestion.json`` (max/mean link congestion for both
placements, reduction, rounds, utilization premium, host vs device driver
seconds, per-round and total device->host bytes, per scenario) plus a CSV.
At the headline scenario (T >= 16 tenants) asserts the driver cuts
max-link congestion by at least ``MIN_REDUCTION`` (15%) while converging
within the round bound, and that the resident loop beats the host-driven
loop by at least ``MIN_DEVICE_SPEEDUP`` (2x) wall-clock — the acceptance
bars for the congestion work.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import bt, sample_load
from repro.engine import solve_batch, solve_congestion

from .common import fmt_table, out_path, write_csv

N_TOTAL = 128
K = 8
T = 16
MAX_ROUNDS = 8
REPS = 2
MIN_REDUCTION = 0.15      # acceptance: >= 15% lower max-link congestion
MIN_DEVICE_SPEEDUP = 2.0  # acceptance: resident loop >= 2x host-driven loop
ASSERT_MIN_T = 16         # ... asserted at the headline T >= 16 scenario


def run(n_total: int = N_TOTAL, k: int = K, tenants=(T,),
        max_rounds: int = MAX_ROUNDS, reps: int = REPS,
        quiet: bool = False):
    t = bt(n_total, "constant")
    rows = []
    bench: list[dict] = []
    for T_i in tenants:
        loads = [sample_load(t, "power-law", seed=s) for s in range(T_i)]
        base = solve_batch([t] * T_i, loads, k)          # warm solve jit
        # warm both driver flavors (each compiles its own executable)
        solve_congestion(t, loads, k, max_rounds=max_rounds)
        solve_congestion(t, loads, k, max_rounds=max_rounds,
                         device_loop=False)
        t_base = min(_timed(lambda: solve_batch([t] * T_i, loads, k))
                     for _ in range(reps))
        # steady-state driver times (jit warm), min over reps — the same
        # discipline for both flavors, so the JSON speedup is honest
        t_dev, res = np.inf, None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = solve_congestion(t, loads, k, max_rounds=max_rounds)
            t_dev = min(t_dev, time.perf_counter() - t0)
            res = r
        t_host, res_host = np.inf, None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = solve_congestion(t, loads, k, max_rounds=max_rounds,
                                 device_loop=False)
            t_host = min(t_host, time.perf_counter() - t0)
            res_host = r
        assert res.history == res_host.history, \
            "device/host driver trajectories diverged"   # bit parity
        util_premium = float(res.costs.sum() / base.costs.sum() - 1.0)
        row = dict(
            T=T_i,
            n_total=n_total,
            k=k,
            baseline_max=res.baseline_max,
            driver_max=res.max_congestion,
            reduction=res.improvement,
            baseline_mean=res.baseline_mean,
            driver_mean=res.mean_congestion,
            rounds=res.rounds,
            best_round=res.best_round,
            util_premium=util_premium,
            solve_s_batch=t_base,
            solve_s_device=t_dev,
            solve_s_host=t_host,
            device_speedup=t_host / t_dev,
            bytes_to_host_device=res.bytes_to_host,
            bytes_to_host_host=res_host.bytes_to_host,
            bytes_per_round_device=res.bytes_to_host / res.rounds,
            bytes_per_round_host=res_host.bytes_to_host / res_host.rounds,
        )
        bench.append(row)
        rows.append(list(row.values()))
        if T_i >= ASSERT_MIN_T and max_rounds >= MAX_ROUNDS:
            assert res.improvement >= MIN_REDUCTION, (
                f"congestion driver reduced max-link congestion by only "
                f"{100 * res.improvement:.1f}% at T={T_i} — below the "
                f"{100 * MIN_REDUCTION:.0f}% bar")
            # converged within the round bound: the final round did not
            # improve (a plateau was reached), it didn't run out of budget
            # mid-descent
            assert res.best_round < res.rounds - 1, (
                f"driver still improving at the round bound "
                f"(best_round={res.best_round}, rounds={res.rounds})")
            assert row["device_speedup"] >= MIN_DEVICE_SPEEDUP, (
                f"device-resident loop only {row['device_speedup']:.2f}x "
                f"the host-driven loop at T={T_i} — below the "
                f"{MIN_DEVICE_SPEEDUP}x bar")
    header = list(bench[0].keys())
    write_csv("congestion.csv", header, rows)
    with open(out_path("BENCH_congestion.json"), "w") as fh:
        json.dump({"n_total": n_total, "k": k, "max_rounds": max_rounds,
                   "min_reduction": MIN_REDUCTION,
                   "min_device_speedup": MIN_DEVICE_SPEEDUP, "rows": bench},
                  fh, indent=2)
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=N_TOTAL)
    ap.add_argument("--k", type=int, default=K)
    ap.add_argument("--tenants", type=str, default=str(T),
                    help="comma-separated tenant counts (the >=15%% "
                         "reduction and >=2x device-speedup asserts only "
                         f"fire at T >= {ASSERT_MIN_T} with the full round "
                         "budget)")
    ap.add_argument("--rounds", type=int, default=MAX_ROUNDS)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args(argv)
    run(n_total=args.n, k=args.k,
        tenants=tuple(int(x) for x in args.tenants.split(",")),
        max_rounds=args.rounds, reps=args.reps)


if __name__ == "__main__":
    main()
