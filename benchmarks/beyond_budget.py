"""Beyond-paper: distinct per-workload budgets (paper §8, open problem 2).

Splits a total aggregation budget K across W concurrent workloads via
greedy-on-concave-envelopes over each workload's SOAR cost curve
(core/budget.py), against (i) the uniform k=K/W split and (ii) the exact
enumeration on small instances.
"""
from __future__ import annotations

import numpy as np

from repro.core import bt, sample_load
from repro.core.budget import allocate_budget, brute_allocate, uniform_allocate

from .common import fmt_table, write_csv


def _mixed_workloads(t, w, seed):
    out = []
    for i in range(w):
        L = sample_load(t, "power-law" if i % 2 else "uniform", seed=seed + i)
        if i == 0:
            L = L * 10          # heterogeneity: one hot tenant
        out.append(L)
    return out


def run(quiet: bool = False, reps: int = 3):
    rows = []
    # exactness check (small): greedy vs brute
    t = bt(16, "constant")
    for r in range(reps):
        ws = _mixed_workloads(t, 3, 100 + r)
        bg, cg = allocate_budget(t, ws, 6)
        bb, cb = brute_allocate(t, ws, 6)
        rows.append(["BT(16) W=3 K=6", r, "greedy_vs_brute", cg / cb,
                     "-".join(map(str, bg))])
    # scale comparison: greedy vs uniform
    for n, w, K, scheme in [(256, 8, 32, "constant"), (256, 8, 32, "linear"),
                            (512, 16, 64, "exponential")]:
        t = bt(n, scheme)
        for r in range(reps):
            ws = _mixed_workloads(t, w, 200 + r)
            _, cg = allocate_budget(t, ws, K)
            _, cu = uniform_allocate(t, ws, K)
            rows.append([f"BT({n}) W={w} K={K} {scheme}", r,
                         "greedy_vs_uniform", cg / cu, ""])
    header = ["scenario", "rep", "comparison", "cost_ratio", "budgets"]
    write_csv("beyond_budget.csv", header, rows)
    for row in rows:
        assert row[3] <= 1.02 + 1e-9, row    # greedy never meaningfully worse
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
