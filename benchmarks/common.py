"""Shared benchmark plumbing: CSV emission, timing, output locations."""
from __future__ import annotations

import csv
import os
import time
from contextlib import contextmanager

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def out_path(name: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, name)


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    path = out_path(name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def fmt_table(header: list[str], rows: list[list], max_rows: int = 40) -> str:
    cols = [header] + [[f"{c:.4f}" if isinstance(c, float) else str(c) for c in r]
                       for r in rows[:max_rows]]
    widths = [max(len(r[i]) for r in cols) for i in range(len(header))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in cols]
    if len(rows) > max_rows:
        lines.append(f"... ({len(rows) - max_rows} more rows)")
    return "\n".join(lines)
