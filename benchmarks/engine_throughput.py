"""Engine throughput: device-resident solve vs the PR 1 path vs serial.

The production question behind the ROADMAP north star: how many placement
instances per second can one process serve? We solve B same-shape
multi-tenant instances (BT(n), power-law loads — the paper's Sec. 5.2
workload) four ways and report instances/sec:

  * ``serial``  — loop `soar_fast` per instance (the pre-engine path);
  * ``pr1``     — `solve_forest(debug_tables=True, cap=False)`: the PR 1
                  batched path (full-width gather, full DP-table pullback
                  to the host, host-numpy color);
  * ``device``  — `solve_batch` default: fused level-fold gather with the
                  subtree-budget cap + on-device color; only `(B, n_max)`
                  masks and `(B,)` costs cross the host/device boundary;
  * ``costs``   — `solve_forest(color=False)`, the costs-only planning
                  mode (capacity pricing / what-if sweeps need no masks).

Timings are steady-state (the jit compile is warmed up and reported
separately); Forest packing is *included* in the batched times — it is
part of the serving path. Besides the CSV, emits ``BENCH_engine.json``
(instances/sec, device->host bytes, compile seconds, per B) so future PRs
can track the perf curve. Asserts the headline claims at B=64:
``device >= MIN_SPEEDUP_PR1 x pr1`` and ``>= MIN_SPEEDUP_SERIAL x serial``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import bt, sample_load
from repro.core.forest import build_forest
from repro.core.soar_fast import soar_fast
from repro.engine import EngineOptions, solve_batch, solve_forest

from .common import fmt_table, out_path, write_csv

N_TOTAL = 128
K = 16
BATCHES = (1, 8, 64)
REPS = 3
MIN_SPEEDUP_SERIAL = 5.0  # acceptance: device >= 5x serial at B=64
MIN_SPEEDUP_PR1 = 2.0     # acceptance: device >= 2x the PR 1 path at B=64


def _time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))   # min: robust to background-load noise


def run(n_total: int = N_TOTAL, k: int = K, batches=BATCHES,
        reps: int = REPS, quiet: bool = False):
    t = bt(n_total, "constant")
    rows = []
    bench: list[dict] = []
    speedup_pr1 = {}
    for B in batches:
        loads = [sample_load(t, "power-law", seed=s) for s in range(B)]
        trees = [t] * B
        t0 = time.perf_counter()
        res = solve_batch(trees, loads, k)           # compile + warm
        t_compile = time.perf_counter() - t0
        pr1_opts = EngineOptions(debug_tables=True, cap=False)
        res_pr1 = solve_batch(trees, loads, k,       # warm the PR 1 path
                              options=pr1_opts)
        serial = [soar_fast(t, L, k) for L in loads]   # warm + sanity oracle
        t_serial = _time(lambda: [soar_fast(t, L, k) for L in loads], reps)
        t_pr1 = _time(lambda: solve_batch(trees, loads, k,
                                          options=pr1_opts), reps)
        t_dev = _time(lambda: solve_batch(trees, loads, k), reps)
        forest = build_forest(trees, loads)
        t_costs = _time(lambda: solve_forest(forest, k, options=EngineOptions(color=False)), reps)
        # sanity: identical costs and bit-identical masks across paths
        assert all(res.costs[b] == serial[b].cost for b in range(B)), \
            "engine/serial cost mismatch"
        assert np.array_equal(res.blue, res_pr1.blue), \
            "device/host color mask mismatch"
        row = dict(
            B=B,
            serial_inst_per_s=B / t_serial,
            pr1_inst_per_s=B / t_pr1,
            device_inst_per_s=B / t_dev,
            costs_only_inst_per_s=B / t_costs,
            speedup_vs_serial=t_serial / t_dev,
            speedup_vs_pr1=t_pr1 / t_dev,
            bytes_to_host_device=res.bytes_to_host,
            bytes_to_host_pr1=res_pr1.bytes_to_host,
            compile_s=t_compile,
        )
        bench.append(row)
        speedup_pr1[B] = row["speedup_vs_pr1"]
        rows.append(list(row.values()))
    header = list(bench[0].keys())
    write_csv("engine_throughput.csv", header, rows)
    with open(out_path("BENCH_engine.json"), "w") as fh:
        json.dump({"n_total": n_total, "k": k, "reps": reps, "rows": bench},
                  fh, indent=2)
    if 64 in speedup_pr1:
        b64 = next(r for r in bench if r["B"] == 64)
        assert b64["speedup_vs_serial"] >= MIN_SPEEDUP_SERIAL, (
            f"device speedup {b64['speedup_vs_serial']:.1f}x over serial at "
            f"B=64 below the {MIN_SPEEDUP_SERIAL}x bar")
        assert b64["speedup_vs_pr1"] >= MIN_SPEEDUP_PR1, (
            f"device speedup {b64['speedup_vs_pr1']:.1f}x over the PR 1 "
            f"path at B=64 below the {MIN_SPEEDUP_PR1}x bar")
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=N_TOTAL)
    ap.add_argument("--k", type=int, default=K)
    ap.add_argument("--batches", type=str, default=",".join(map(str, BATCHES)),
                    help="comma-separated batch sizes (the B=64 speedup "
                         "asserts only fire when 64 is included)")
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args(argv)
    run(n_total=args.n, k=args.k,
        batches=tuple(int(b) for b in args.batches.split(",")),
        reps=args.reps)


if __name__ == "__main__":
    main()
