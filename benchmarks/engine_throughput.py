"""Engine throughput: batched `solve_batch` vs a serial `soar_fast` loop.

The production question behind the ROADMAP north star: how many placement
instances per second can one process serve? We solve B same-shape
multi-tenant instances (BT(n), power-law loads — the paper's Sec. 5.2
workload) three ways and report instances/sec:

  * ``serial``  — loop `soar_fast` per instance (the pre-engine path);
  * ``batched`` — one `solve_forest` call (gather + batched color);
  * ``costs``   — `solve_forest(color=False)`, the costs-only planning
                  mode (capacity pricing / what-if sweeps need no masks).

Timings are steady-state (the jit compile is warmed up and reported
separately); Forest packing is *included* in the batched time — it is part
of the serving path. Asserts the headline claim: >= MIN_SPEEDUP x
instances/sec at B=64.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import bt, sample_load
from repro.core.forest import build_forest
from repro.core.soar_fast import soar_fast
from repro.engine import solve_batch, solve_forest

from .common import fmt_table, write_csv

N_TOTAL = 128
K = 16
BATCHES = (1, 8, 64)
REPS = 3
MIN_SPEEDUP = 5.0     # acceptance: batched >= 5x serial at B=64


def _time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))   # min: robust to background-load noise


def run(n_total: int = N_TOTAL, k: int = K, batches=BATCHES,
        reps: int = REPS, quiet: bool = False):
    t = bt(n_total, "constant")
    rows = []
    speedup_at = {}
    for B in batches:
        loads = [sample_load(t, "power-law", seed=s) for s in range(B)]
        trees = [t] * B
        t0 = time.perf_counter()
        res = solve_batch(trees, loads, k)           # compile + warm
        t_compile = time.perf_counter() - t0
        t_serial = _time(lambda: [soar_fast(t, L, k) for L in loads], reps)
        t_batch = _time(lambda: solve_batch(trees, loads, k), reps)
        forest = build_forest(trees, loads)
        t_costs = _time(lambda: solve_forest(forest, k, color=False), reps)
        # sanity: identical optimal costs (constant rates are dyadic-exact)
        serial = [soar_fast(t, L, k) for L in loads]
        assert all(res.costs[b] == serial[b].cost for b in range(B)), \
            "engine/serial cost mismatch"
        speedup = t_serial / t_batch
        speedup_at[B] = speedup
        rows.append([B, B / t_serial, B / t_batch, B / t_costs,
                     speedup, t_compile])
    header = ["B", "serial_inst_per_s", "batched_inst_per_s",
              "costs_only_inst_per_s", "speedup", "compile_s"]
    write_csv("engine_throughput.csv", header, rows)
    if 64 in speedup_at:
        assert speedup_at[64] >= MIN_SPEEDUP, (
            f"engine speedup {speedup_at[64]:.1f}x at B=64 "
            f"below the {MIN_SPEEDUP}x bar")
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
