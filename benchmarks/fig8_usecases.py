"""Fig. 8: WC / PS use cases — utilization vs byte complexity.

BT(256), constant rates, uniform + power-law loads, k sweep. (a) normalized
utilization (use-case independent); (b) normalized byte complexity vs
all-red; (c) byte complexity vs the all-blue solution.
"""
from __future__ import annotations

import numpy as np

from repro.core import all_blue, all_red, bt, phi, sample_load, soar_fast
from repro.core.bytes_model import (ParameterServerModel, WordCountModel,
                                    byte_complexity)

from .common import fmt_table, write_csv

KS = (1, 2, 4, 8, 16, 32)
N_TOTAL = 256
REPS = 5


def run(n_total: int = N_TOTAL, reps: int = REPS, quiet: bool = False):
    t = bt(n_total, "constant")
    wc = WordCountModel(n_servers=int(5 * len(t.leaves)))
    ps = ParameterServerModel()
    rows = []
    for dist in ("power-law", "uniform"):
        loads = [sample_load(t, dist, seed=r) for r in range(reps)]
        red = all_red(t)
        blue_all = all_blue(t)
        norm = {
            "util": [phi(t, L, red) for L in loads],
            "wc": [byte_complexity(t, L, red, wc.size) for L in loads],
            "ps": [byte_complexity(t, L, red, ps.size) for L in loads],
        }
        blue_ref = {
            "wc": [byte_complexity(t, L, blue_all, wc.size) for L in loads],
            "ps": [byte_complexity(t, L, blue_all, ps.size) for L in loads],
        }
        for k in KS:
            util, wcb, psb, wc_vs_blue, ps_vs_blue = [], [], [], [], []
            for i, L in enumerate(loads):
                sol = soar_fast(t, L, k)
                util.append(sol.cost / norm["util"][i])
                bwc = byte_complexity(t, L, sol.blue, wc.size)
                bps = byte_complexity(t, L, sol.blue, ps.size)
                wcb.append(bwc / norm["wc"][i])
                psb.append(bps / norm["ps"][i])
                wc_vs_blue.append(bwc / blue_ref["wc"][i])
                ps_vs_blue.append(bps / blue_ref["ps"][i])
            rows.append([dist, k, float(np.mean(util)), float(np.mean(wcb)),
                         float(np.mean(psb)), float(np.mean(wc_vs_blue)),
                         float(np.mean(ps_vs_blue))])
    header = ["load", "k", "util_vs_red", "wc_bytes_vs_red", "ps_bytes_vs_red",
              "wc_bytes_vs_blue", "ps_bytes_vs_blue"]
    write_csv("fig8_usecases.csv", header, rows)
    # paper claims: (i) PS byte complexity tracks utilization closely;
    # (ii) WC approaches the all-blue bound with few blue nodes.
    for dist, k, util, wcb, psb, wcvb, psvb in rows:
        assert abs(psb - util) < 0.12, (dist, k, util, psb)
        if k >= 16:
            assert wcvb < 1.9, (dist, k, wcvb)
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
