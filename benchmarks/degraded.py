"""Degraded-mode benchmark: partial-capacity premiums + chaos training.

Two measurements of the execution-layer fault-tolerance path:

  * **partial-capacity premium** — degrade each initially-blue switch to
    each capacity fraction in ``CAP_FRACS`` and measure the utilization
    premium of the *instant* no-solve degraded program (the same blue
    set spilling its overflow one hop up) over the subsequently
    replanned placement — how much utilization the bounded-regression
    fallback costs while the replan lands. Acceptance: the mean instant
    premium stays under ``MAX_MEAN_PREMIUM`` (30%) — degraded mode is a
    bounded regression, not a cliff. Premiums over the fault-free
    baseline are reported alongside for context (those include the
    unavoidable overflow traffic the replan itself pays);
  * **training under chaos** — a seeded ``>= 50``-event scenario that
    includes partial-capacity degrade events drives *real* training
    steps (one per event, tiny model) through
    :class:`~repro.runtime.ChaosTrainer`, with every harness invariant
    checked per event and every lossless recovery asserted bit-identical
    to the fault-free program. Acceptance: zero invariant violations
    (the harness raises otherwise) and the median non-compile step time
    under chaos within ``MAX_THROUGHPUT_LOSS`` (25%) of a fault-free run
    of the same trainer.

Emits ``BENCH_degraded.json`` + a CSV of the per-(switch, fraction)
premium sweep.
"""
from __future__ import annotations

import argparse
import json
import tempfile
from collections import Counter

import numpy as np

from repro.collectives import fleet_tree
from repro.runtime import (ChaosHarness, Orchestrator, OrchestratorConfig,
                           generate_scenario)
from repro.runtime.faults import CAP_FRACS

from .common import fmt_table, out_path, write_csv

N_PODS = 4
RACKS = 4
CHIPS = 4
K = 6
CAPACITY = 2
EVENTS = 50
SEED = 0
TRAIN_SEQ = 32
TRAIN_BATCH = 4
MAX_MEAN_PREMIUM = 0.30      # acceptance: mean instant premium <= 30%
MAX_THROUGHPUT_LOSS = 0.25   # acceptance: chaos step time within 25% of ff


def _bench_premium(topo, cfg):
    """Degrade every initially-blue switch at every CAP_FRACS fraction."""
    base = Orchestrator(topo, cfg)
    u0 = base.program.utilization
    rows, instant, vs_base = [], [], []
    for s in np.nonzero(base.blue)[0]:
        for f in CAP_FRACS:
            orch = Orchestrator(topo, cfg)
            orch.on_switch_degrade({int(s): float(f)})
            ev = orch.degraded_events[-1]
            pi = ev["degraded_utilization"] / ev["utilization"] - 1.0
            pb = ev["utilization"] / u0 - 1.0
            instant.append(pi)
            vs_base.append(pb)
            rows.append([int(s), f, ev["degraded_utilization"],
                         ev["utilization"], pi, pb])
    return {
        "baseline_utilization": u0,
        "cases": len(rows),
        "mean_instant_premium": float(np.mean(instant)),
        "max_instant_premium": float(np.max(instant)),
        "mean_replanned_vs_baseline": float(np.mean(vs_base)),
        "max_replanned_vs_baseline": float(np.max(vs_base)),
    }, rows


def _bench_train_chaos(events, seed, seq, batch):
    """Real training steps under a degrade-heavy chaos scenario."""
    import jax

    from repro.launch.train import dp_fleet
    from repro.runtime import ChaosTrainer

    n_dev = jax.device_count()
    topo = dp_fleet(n_dev)
    cfg = OrchestratorConfig(k=min(2, topo.tree.n))
    scenario = generate_scenario(topo, n_events=events, seed=seed, cfg=cfg,
                                 train=True)
    kinds = Counter(e.kind for e in scenario)
    assert kinds["degrade_switch"] > 0, \
        "scenario must include partial-capacity degrade events"

    # fault-free control: the same trainer, no events — just steps
    ff = ChaosTrainer(Orchestrator(topo, cfg), seq=seq, global_batch=batch,
                      seed=seed)
    for _ in range(events):
        ff.train_step()
    ff_sum = ff.summary()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = ChaosTrainer(Orchestrator(topo, cfg), seq=seq,
                               global_batch=batch, seed=seed,
                               ckpt_dir=ckpt_dir)
        orch = trainer.orch
        report = ChaosHarness(orch, trainer=trainer).run(scenario)
    tr = report.train
    loss_frac = (None if not ff_sum["median_step_seconds"]
                 else tr["median_step_seconds"]
                 / ff_sum["median_step_seconds"] - 1.0)
    return {
        "devices": n_dev,
        "events": report.events,
        "event_kinds": dict(kinds),
        "invariant_checks": report.invariant_checks,
        "replans": report.replans,
        "cache_hits": report.cache_hits,
        "steps": tr["steps"],
        "bitwise_checks": tr["bitwise_checks"],
        "restores": tr["restores"],
        "compiles": tr["compiles"],
        "first_loss": tr["first_loss"],
        "last_loss": tr["last_loss"],
        "median_step_seconds": tr["median_step_seconds"],
        "fault_free_median_step_seconds": ff_sum["median_step_seconds"],
        "throughput_loss": loss_frac,
    }


def run(n_pods: int = N_PODS, racks: int = RACKS, chips: int = CHIPS,
        k: int = K, capacity: int = CAPACITY, events: int = EVENTS,
        seed: int = SEED, seq: int = TRAIN_SEQ, batch: int = TRAIN_BATCH,
        quiet: bool = False):
    topo = fleet_tree(n_pods, racks, chips)
    cfg = OrchestratorConfig(k=k, capacity=capacity)

    premium, rows = _bench_premium(topo, cfg)
    train = _bench_train_chaos(events, seed, seq, batch)

    write_csv("BENCH_degraded.csv",
              ["switch", "fraction", "degraded_util", "replanned_util",
               "instant_premium", "replanned_premium"], rows)
    payload = {
        "n_pods": n_pods, "racks_per_pod": racks, "chips_per_rack": chips,
        "k": k, "capacity": capacity, "events": events, "seed": seed,
        "premium": premium,
        "train_chaos": train,
    }
    with open(out_path("BENCH_degraded.json"), "w") as fh:
        json.dump(payload, fh, indent=2)

    if not quiet:
        print(fmt_table(["switch", "frac", "deg_util", "replan_util",
                         "instant", "vs_base"], rows, max_rows=12))
        print(f"\ninstant-over-replanned premium: "
              f"mean {premium['mean_instant_premium']:.1%} "
              f"max {premium['max_instant_premium']:.1%}; replanned over "
              f"fault-free phi={premium['baseline_utilization']:.0f}: mean "
              f"{premium['mean_replanned_vs_baseline']:.1%} "
              f"({premium['cases']} cases)")
        print(f"chaos training: {train['events']} events / {train['steps']} "
              f"steps on {train['devices']} device(s), "
              f"{train['bitwise_checks']} bitwise checks, "
              f"{train['restores']} checkpoint restarts, loss "
              f"{train['first_loss']:.3f} -> {train['last_loss']:.3f}")
        if train["throughput_loss"] is not None:
            print(f"step time: {train['median_step_seconds']*1e3:.1f}ms "
                  f"under chaos vs "
                  f"{train['fault_free_median_step_seconds']*1e3:.1f}ms "
                  f"fault-free ({train['throughput_loss']:+.1%})")

    assert premium["mean_instant_premium"] <= MAX_MEAN_PREMIUM, (
        f"mean instant degraded premium "
        f"{premium['mean_instant_premium']:.1%} exceeds "
        f"{MAX_MEAN_PREMIUM:.0%}")
    assert train["invariant_checks"] == events
    if train["throughput_loss"] is not None:
        assert train["throughput_loss"] <= MAX_THROUGHPUT_LOSS, (
            f"training throughput loss {train['throughput_loss']:.1%} "
            f"under chaos exceeds {MAX_THROUGHPUT_LOSS:.0%}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--pods", type=int, default=N_PODS)
    ap.add_argument("--racks", type=int, default=RACKS)
    ap.add_argument("--k", type=int, default=K)
    ap.add_argument("--seq", type=int, default=TRAIN_SEQ)
    args = ap.parse_args(argv)
    run(n_pods=args.pods, racks=args.racks, k=args.k, events=args.events,
        seed=args.seed, seq=args.seq)


if __name__ == "__main__":
    main()
