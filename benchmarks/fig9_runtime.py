"""Fig. 9: SOAR running time vs network size and budget k.

Paper: serial SOAR-Gather seconds-to-minutes for n<=2048, k<=128; Color is
~1000x faster than Gather. We time the faithful implementation (the paper's
serial loop structure), our vectorized level-synchronous rewrite, AND the
batched JAX engine (`repro.engine`) amortized over ENGINE_B same-shape
instances — the multi-tenant serving configuration.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import bt, sample_load
from repro.core.soar import soar_color, soar_gather
from repro.core.soar_fast import soar_gather_vectorized
from repro.engine import solve_forest
from repro.core.forest import build_forest

from .common import fmt_table, write_csv

SIZES = (256, 512, 1024, 2048)
KS = (4, 8, 16, 32, 64, 128)
REPS = 3
ENGINE_B = 16          # engine batch width for the amortized column


def _time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(sizes=SIZES, ks=KS, reps: int = REPS, quiet: bool = False,
        faithful_limit: int = 2048, engine_b: int = ENGINE_B):
    rows = []
    for n in sizes:
        t = bt(n, "constant")
        L = sample_load(t, "power-law", seed=0)
        loads = [sample_load(t, "power-law", seed=s) for s in range(engine_b)]
        forest = build_forest([t] * engine_b, loads)
        for k in ks:
            # the faithful O(n h k^2) loop gets slow; cap its largest cells
            run_faithful = n * k * k <= faithful_limit * 128 * 128
            t_gather = (_time(lambda: soar_gather(t, L, k, cap=False), reps)
                        if run_faithful else float("nan"))
            t_fast = _time(lambda: soar_gather_vectorized(t, L, k), reps)
            X_all = soar_gather_vectorized(t, L, k)
            X = [X_all[v] for v in range(t.n)]
            t_color = _time(lambda: soar_color(t, L, k, X), reps)
            solve_forest(forest, k)          # compile once, then steady-state
            t_engine = _time(lambda: solve_forest(forest, k), reps) / engine_b
            rows.append([n, k, t_gather, t_fast, t_color, t_engine,
                         (t_gather / t_fast) if run_faithful else float("nan"),
                         (t_fast + t_color) / t_engine])
    header = ["n", "k", "gather_faithful_s", "gather_fast_s", "color_s",
              "engine_per_inst_s", "speedup_fast", "speedup_engine"]
    write_csv("fig9_runtime.csv", header, rows)
    # paper claim: Color runs orders of magnitude faster than Gather
    for n, k, tg, tf, tc, te, sf, se in rows:
        if not np.isnan(tg):
            assert tc < tg, (n, k, tc, tg)
    if not quiet:
        print(fmt_table(header, rows, max_rows=len(rows)))
    return header, rows


if __name__ == "__main__":
    run()
