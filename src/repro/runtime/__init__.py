from .orchestrator import (JobRecord, Orchestrator, OrchestratorConfig,
                           PreemptionPolicy)
from .stragglers import StragglerPolicy, StragglerReport
from .elastic import fleet_dims, rescale, scaling_budget
from .faults import (ChaosHarness, ChaosReport, ChaosTrainer,
                     FaultEvent, InvariantViolation,
                     generate_scenario)

__all__ = ["JobRecord", "Orchestrator", "OrchestratorConfig",
           "PreemptionPolicy", "StragglerPolicy",
           "StragglerReport", "fleet_dims", "rescale", "scaling_budget",
           "ChaosHarness", "ChaosReport", "ChaosTrainer", "FaultEvent",
           "InvariantViolation", "generate_scenario"]
