from .orchestrator import Orchestrator, OrchestratorConfig
from .stragglers import StragglerPolicy, StragglerReport
from .elastic import rescale

__all__ = ["Orchestrator", "OrchestratorConfig", "StragglerPolicy",
           "StragglerReport", "rescale"]
