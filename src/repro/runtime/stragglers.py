"""Straggler detection and mitigation for the SOAR reduction pipeline.

A blue (aggregating) switch *waits* for all children before emitting its
message (paper Sec. 4.4: aggregating nodes hold until all inputs arrive),
so a single slow device stalls every barrier on its root path — straggling
is strictly more harmful under in-network aggregation than under
store-and-forward. The policy here is the standard production recipe:

  * per-step device durations are folded into an EWMA profile;
  * a device is a *suspect* when its duration exceeds
    ``deadline = quantile(durations, q) * slack``;
  * persistent suspects (``patience`` consecutive suspect steps) are
    *quarantined*: the orchestrator treats them as failed for placement
    purposes (drop-from-reduce with gradient renormalization) until they
    recover or are replaced.

Quarantine feeds back into SOAR: the reduction tree loses the quarantined
chip's load, and the budget is re-sown over the remaining topology.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    suspects: np.ndarray       # (n_dev,) bool — slow this step
    quarantined: np.ndarray    # (n_dev,) bool — persistently slow
    deadline: float            # the step's cut-off in seconds


class StragglerPolicy:
    """Deadline + patience straggler tracker."""

    def __init__(self, n_devices: int, quantile: float = 0.9,
                 slack: float = 2.0, patience: int = 3,
                 ewma: float = 0.5):
        if not 0.0 < quantile < 1.0:
            raise ValueError("quantile must be in (0, 1)")
        if slack < 1.0:
            raise ValueError("slack must be >= 1")
        self.quantile = quantile
        self.slack = slack
        self.patience = patience
        self.ewma = ewma
        self._profile = np.zeros(n_devices)
        self._strikes = np.zeros(n_devices, np.int64)
        self._observed = np.zeros(n_devices, bool)

    def observe(self, durations: np.ndarray,
                alive: np.ndarray | None = None) -> StragglerReport:
        """Fold one step's per-device durations; return suspects/quarantine.

        ``alive`` masks the devices that actually ran this step: dead or
        quarantined devices keep their (stale) EWMA entries but are
        excluded from the deadline quantile — otherwise a dead slow
        device's frozen profile inflates the cutoff forever and live
        stragglers sail under it — and can never be suspects.
        """
        d = np.asarray(durations, dtype=np.float64)
        if d.shape != self._profile.shape:
            raise ValueError(f"expected {self._profile.shape}, got {d.shape}")
        if alive is None:
            alive = np.ones_like(self._profile, dtype=bool)
        else:
            alive = np.asarray(alive, dtype=bool)
            if alive.shape != self._profile.shape:
                raise ValueError(f"expected alive mask {self._profile.shape},"
                                 f" got {alive.shape}")
        first = alive & ~self._observed
        folded = self.ewma * d + (1 - self.ewma) * self._profile
        self._profile = np.where(first, d,
                                 np.where(alive, folded, self._profile))
        self._observed |= alive
        if alive.any():
            deadline = float(
                np.quantile(self._profile[alive], self.quantile)) * self.slack
            suspects = alive & (self._profile > deadline)
        else:
            deadline = float("inf")
            suspects = np.zeros_like(alive)
        self._strikes = np.where(suspects, self._strikes + 1, 0)
        return StragglerReport(
            suspects=suspects,
            quarantined=self._strikes >= self.patience,
            deadline=deadline,
        )

    def clear(self, device: int) -> None:
        """Forget history for a replaced/recovered device."""
        self._strikes[device] = 0
        ref = self._profile[self._observed]
        self._profile[device] = float(np.median(ref)) if len(ref) else 0.0
        self._observed[device] = True
