"""Fault-tolerant orchestration: failures/stragglers -> SOAR re-placement.

The orchestrator owns the cluster reduction tree, the current blue
placement, and the compiled-in ReduceProgram. Every topology event —
device failure, *switch aggregation-plane failure*, *link-rate
degradation*, straggler quarantine, elastic rescale — funnels into the
same recovery path the paper's model makes cheap:

    update tree/load/Lambda -> SOAR re-sow (O(n h k^2), milliseconds at
    fleet scale) -> rebuild the static reduction program -> resume.

Recovery is *bounded* and comes in two speeds:

  * **degraded mode** (switch failures only): a dead blue switch reverts
    to plain forwarding immediately — the program is rebuilt from the
    surviving blue set with *no* solve, so the utilization regression is
    bounded by that one switch's aggregation saving (never worse than the
    all-red fallback);
  * **preplanned recovery**: what-if placements from ``preplan_failures``
    / ``preplan_switch_failures`` (and every placement the orchestrator
    has already solved) live in a fingerprint-keyed cache. A recovery
    whose post-event topology fingerprint is cached — and whose capacity
    availability still matches the snapshot the entry was solved under —
    is a table lookup, not an engine solve. Hit/miss/stale counters
    surface through :meth:`Orchestrator.preplan_cache_stats`, next to
    the engine's compile-cache telemetry.

The budget k and per-switch aggregation capacity (Sec. 5.2) are respected
across re-placements, so a tenant can never grab more in-network
resources by failing chips or switches.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..collectives.schedule import (ReduceProgram, build_program, plan,
                                    plan_batch, plan_congestion, plan_fleet)
from ..collectives.topology import (ClusterTopology, Fleet, degrade_links,
                                    degrade_switches, fail_devices)
from .stragglers import StragglerPolicy, StragglerReport


def _switch_id(v, n: int, what: str = "switch") -> int:
    """Validate a switch id: integral and in range. ``2.7`` raises instead
    of silently truncating to switch 2."""
    iv = int(v)
    if float(v) != iv:
        raise ValueError(f"{what} id {v!r} is not an integer")
    if not 0 <= iv < n:
        raise ValueError(f"{what} {iv} out of range [0, {n})")
    return iv


@dataclasses.dataclass
class OrchestratorConfig:
    k: int = 4                       # blue-switch budget for this workload
    strategy: str = "soar"           # placement strategy (soar | baselines)
    capacity: int | None = None      # per-switch aggregation capacity a(s)
    straggler_quantile: float = 0.9
    straggler_slack: float = 2.0
    straggler_patience: int = 3


@dataclasses.dataclass(frozen=True)
class JobRecord:
    """One admitted workload's claim on the fleet's capacity ledgers.

    Every admission path files one of these in ``Orchestrator.jobs``, so
    the per-switch conservation invariant — claims + residual ==
    effective capacity — is auditable, and the preemption policies have
    real victims to order. ``benefit`` is the utilization the job's
    in-network aggregation saves vs the all-red fallback (the regression
    preempting it would cost), snapshotted at admission.
    """

    job_id: int
    tree: int                 # fleet tree the claims live on
    blue: np.ndarray          # (n,) bool claim mask (mutated by evictions)
    priority: int             # higher = evicted later
    order: int                # admission sequence number (age)
    utilization: float
    benefit: float


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """Which existing claims to evict when admission cannot fit a wave.

    ``kind`` picks the victim ordering:

    * ``"priority"`` — lowest ``priority`` first (ties: youngest first);
    * ``"youngest-first"`` — most recently admitted first (the classic
      make-room-for-the-old-guard policy);
    * ``"cheapest-regression"`` — smallest aggregation ``benefit`` first,
      so the utilization lost by evicting is minimal.

    ``max_victims`` bounds one admission wave's evictions — preemption
    reuses the two-stage instant-degrade-then-replan shape of
    :meth:`Orchestrator.on_switch_failure`: victims release their claims
    instantly (no solve), then the wave re-solves once against the freed
    ledger.
    """

    kind: str = "priority"
    max_victims: int = 8

    KINDS = ("priority", "youngest-first", "cheapest-regression")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown preemption policy {self.kind!r}; "
                             f"pick one of {self.KINDS}")
        if self.max_victims < 1:
            raise ValueError(f"max_victims must be >= 1, "
                             f"got {self.max_victims}")

    def order_victims(self, jobs: list) -> list:
        """Candidate jobs in eviction order (first = first evicted)."""
        if self.kind == "priority":
            return sorted(jobs, key=lambda j: (j.priority, -j.order))
        if self.kind == "youngest-first":
            return sorted(jobs, key=lambda j: -j.order)
        return sorted(jobs, key=lambda j: (j.benefit, -j.order))


class Orchestrator:
    """Owns topology -> placement -> program; replans on events."""

    def __init__(self, topo: ClusterTopology | Fleet,
                 cfg: OrchestratorConfig):
        self.cfg = cfg
        # the orchestrator's own workload lives on the fleet's first tree;
        # a plain topology is the degenerate single-tree fleet (N=1, no
        # shared core) — one code path, not two
        if isinstance(topo, Fleet):
            self.fleet = topo
            topo = topo.topos[0]
        else:
            self.fleet = Fleet.single(topo)
        self.topo0 = topo
        self.topo = topo
        n = topo.tree.n
        self.alive = np.ones(topo.n_devices, bool)
        self.quarantined = np.zeros(topo.n_devices, bool)
        self.switch_blocked = np.zeros(n, bool)   # dead aggregation planes
        self._link_rate = np.ones(n)              # up-link rate fraction
        self._switch_scale = np.ones(n)           # aggregation-capacity
                                                  # fraction vs pristine
        # residual aggregation capacity (None = unbounded); one ledger per
        # fleet tree — index 0 IS self._residual (same array object)
        self._residual = (np.full(n, cfg.capacity, np.int64)
                          if cfg.capacity is not None else None)
        self._residuals = [self._residual] + [
            np.full(tp.tree.n, cfg.capacity, np.int64)
            if cfg.capacity is not None else None
            for tp in self.fleet.topos[1:]]
        # shared-core rates join every fingerprint: a placement solved
        # against one core pricing must not serve a different one
        self._core_key = self.fleet.core_rho.tobytes()
        self.stragglers = StragglerPolicy(
            topo.n_devices, quantile=cfg.straggler_quantile,
            slack=cfg.straggler_slack, patience=cfg.straggler_patience)
        self.replans = 0
        self.cache_recoveries = 0     # recoveries served without a solve
        self.utilization_history: list[float] = []
        self.degraded_events: list[dict] = []
        self.blue: np.ndarray | None = None
        self.program: ReduceProgram | None = None
        self.last_congestion = None   # CongestionResult of the most recent
                                      # congestion-aware admission
        # multi-job claim registry: every admission path files a JobRecord
        # here (the orchestrator's own workload is NOT a job — it is never
        # preempted); preemption orders its victims out of this registry
        self.jobs: dict[int, JobRecord] = {}
        self._job_seq = 0
        self._allred_util: dict[int, float] = {}   # per-tree baseline cache
        self.preemption_events: list[dict] = []
        self.last_admission: dict | None = None    # telemetry of the most
                                                   # recent begin_workloads
        # device-admission preplan cache: the base fingerprint extended
        # with (count, residual snapshot) — a separate store so the base
        # recovery cache's staleness accounting is untouched
        self._admission_cache: dict = {}
        # preplan cache: topology fingerprint -> solved placement. Filled by
        # preplan_failures / preplan_switch_failures and by every solve the
        # orchestrator performs (revisited states are lookups).
        self._preplan: dict = {}
        self._preplan_stats = {"hits": 0, "misses": 0, "stale": 0}
        self._topo_epoch = 0          # bumped on rescale: old entries die
        self._replace()

    # -- properties ----------------------------------------------------------
    @property
    def n_alive(self) -> int:
        return int((self.alive & ~self.quarantined).sum())

    @property
    def grad_scale(self) -> float:
        """Gradient renormalization: mean over contributing devices."""
        return self.topo0.n_devices / max(1, self.n_alive)

    # -- internal ------------------------------------------------------------
    def _avail(self) -> np.ndarray | None:
        if self._residual is None:
            return None
        return self._residual > 0

    def _replan_avail(self) -> np.ndarray | None:
        """Capacity availability a replan sees: own claim released first."""
        if self._residual is None:
            return None
        r = self._residual.copy()
        if self.blue is not None:
            r[self.blue] += 1
        return r > 0

    def _fingerprint(self, dead: tuple | None = None,
                     blocked: tuple | None = None,
                     link_rate: np.ndarray | None = None,
                     cap_scale: np.ndarray | None = None,
                     tree: int = 0) -> tuple:
        """Hashable key of everything the placement solve depends on:
        the fleet tree id, dead devices, blocked switches, link rates
        (current, or a what-if override), per-switch capacity scales,
        the shared-core rates, budget, strategy, and the topology epoch
        (rescales invalidate everything)."""
        if dead is None:
            dead = tuple(
                np.nonzero(~self.alive | self.quarantined)[0].tolist())
        if blocked is None:
            blocked = tuple(np.nonzero(self.switch_blocked)[0].tolist())
        lr = self._link_rate if link_rate is None else link_rate
        cs = self._switch_scale if cap_scale is None else cap_scale
        return (self._topo_epoch, int(tree), dead, blocked, lr.tobytes(),
                cs.tobytes(), self._core_key, self.cfg.k, self.cfg.strategy)

    def _preplan_store(self, fp: tuple, blue: np.ndarray, util: float,
                       avail: np.ndarray | None) -> None:
        self._preplan[fp] = {
            "blue": np.array(blue, dtype=bool, copy=True),
            "util": float(util),
            # the capacity snapshot the solve ran under; compared at lookup
            # time so a shifted capacity landscape invalidates the entry
            "avail_key": None if avail is None
            else np.asarray(avail, bool).tobytes(),
        }

    def _replace(self) -> None:
        """(Re)compute the SOAR placement + program with an engine solve."""
        if self._residual is not None and self.blue is not None:
            self._residual[self.blue] += 1  # release the old claim
        avail = self._avail()
        self.blue, self.program = plan(
            self.topo, self.cfg.k, avail=avail, strategy=self.cfg.strategy)
        if self._residual is not None:
            self._residual[self.blue] -= 1
        self.replans += 1
        self.utilization_history.append(self.program.utilization)
        # memoize: landing in this exact topology state again (e.g. the
        # mirror recovery of this event) becomes a table lookup
        self._preplan_store(self._fingerprint(), self.blue,
                            self.program.utilization, avail)

    def _apply_cached(self, entry: dict) -> None:
        """Install a preplanned placement: claim swap + program rebuild,
        no engine solve."""
        blue = entry["blue"].copy()
        if self._residual is not None and self.blue is not None:
            self._residual[self.blue] += 1
        program = build_program(self.topo, blue)
        if self._residual is not None:
            self._residual[blue] -= 1
        self.blue = blue
        self.program = program
        self.cache_recoveries += 1
        self.utilization_history.append(program.utilization)

    def _recover(self) -> bool:
        """Cache-or-solve re-placement after a topology event.

        Returns True when the preplan cache served the recovery (no
        engine solve). A cached entry is *stale* — counted, evicted, and
        solved around — when the capacity availability it was computed
        under no longer matches what this replan would see (another
        workload claimed or released switches in the meantime).
        """
        fp = self._fingerprint()
        entry = self._preplan.get(fp)
        if entry is not None:
            avail = self._replan_avail()
            key = None if avail is None else avail.tobytes()
            if key == entry["avail_key"]:
                self._preplan_stats["hits"] += 1
                self._apply_cached(entry)
                return True
            self._preplan_stats["stale"] += 1
            del self._preplan[fp]
        else:
            self._preplan_stats["misses"] += 1
        self._replace()
        return False

    def _scenario_topo(self, dead: list[int],
                       link_rate: np.ndarray | None = None
                       ) -> ClusterTopology:
        """Effective topology for a given dead-device set, with the current
        (or what-if override) link degradations and blocked switches
        applied."""
        lr = self._link_rate if link_rate is None else link_rate
        topo = fail_devices(self.topo0, list(dead))
        if (lr != 1.0).any():
            topo = degrade_links(
                topo, {int(v): float(f)
                       for v, f in enumerate(lr) if f != 1.0})
        if (self._switch_scale != 1.0).any():
            topo = degrade_switches(
                topo, {int(v): float(f)
                       for v, f in enumerate(self._switch_scale)
                       if f != 1.0})
        if self.switch_blocked.any():
            topo = dataclasses.replace(topo,
                                       blocked=self.switch_blocked.copy())
        return topo

    def _effective_topo(self) -> ClusterTopology:
        dead = np.nonzero(~self.alive | self.quarantined)[0]
        return self._scenario_topo(list(dead))

    # -- event handlers -------------------------------------------------------
    def on_failure(self, devices: list[int]) -> ReduceProgram:
        """Hard failure: chips stop producing gradient messages.

        Validates every id before touching any state (and collapses
        duplicates), so a bad id mid-list cannot leave the orchestrator
        half-applied — same discipline as :meth:`on_recover` and
        :func:`~repro.collectives.topology.fail_devices`. Recovery goes
        through the preplan cache (:meth:`preplan_failures`) before
        falling back to an engine solve.
        """
        devices = list(dict.fromkeys(int(d) for d in devices))
        for d in devices:
            if not 0 <= d < len(self.alive):
                raise ValueError(f"device {d} out of range "
                                 f"[0, {len(self.alive)})")
            if not self.alive[d]:
                raise ValueError(f"device {d} already dead")
        # quarantined devices don't count towards n_alive, so only the
        # non-quarantined failures reduce it — reject before mutating
        if sum(1 for d in devices if not self.quarantined[d]) >= self.n_alive:
            raise RuntimeError("all devices failed")
        for d in devices:
            self.alive[d] = False
        self.topo = self._effective_topo()
        self._recover()
        return self.program

    def on_switch_failure(self, switches: list[int]) -> ReduceProgram:
        """A switch's aggregation plane dies; forwarding survives.

        Two-stage recovery (the in-network-computing fault model — P4COM
        handles aggregator loss with a fallback transport the same way):

        1. **degraded mode** — any failed switch that is currently blue
           reverts to plain forwarding *immediately*: its capacity claim
           is released and the program is rebuilt from the surviving blue
           set with no engine solve. The utilization regression is
           bounded — exactly the dead switches' aggregation saving, never
           worse than all-red — and recorded in ``degraded_events``.
        2. **replan** — cache-or-solve through the preplan cache
           (:meth:`preplan_switch_failures` makes step 2 a table lookup
           for every preplanned single-switch failure).
        """
        switches = list(dict.fromkeys(int(s) for s in switches))
        n = self.topo0.tree.n
        for s in switches:
            if not 0 <= s < n:
                raise ValueError(f"switch {s} out of range [0, {n})")
            if self.switch_blocked[s]:
                raise ValueError(f"switch {s} already failed")
        for s in switches:
            self.switch_blocked[s] = True
        self.topo = self._effective_topo()
        degraded_util = None
        was_blue = [s for s in switches
                    if self.blue is not None and self.blue[s]]
        if was_blue:
            deg_blue = self.blue.copy()
            deg_blue[was_blue] = False
            if self._residual is not None:
                self._residual[was_blue] += 1   # dead blues release claims
            self.program = build_program(self.topo, deg_blue)
            self.blue = deg_blue
            degraded_util = self.program.utilization
        hit = self._recover()
        self.degraded_events.append({
            "switches": tuple(switches),
            "was_blue": tuple(was_blue),
            "degraded_utilization": degraded_util,
            "utilization": self.program.utilization,
            "cache_hit": hit,
        })
        return self.program

    def on_switch_recover(self, switches: list[int]) -> ReduceProgram:
        """A repaired aggregation plane rejoins the candidate set."""
        switches = list(dict.fromkeys(int(s) for s in switches))
        n = self.topo0.tree.n
        for s in switches:
            if not 0 <= s < n:
                raise ValueError(f"switch {s} out of range [0, {n})")
            if not self.switch_blocked[s]:
                raise ValueError(f"switch {s} is not failed")
        for s in switches:
            self.switch_blocked[s] = False
        self.topo = self._effective_topo()
        self._recover()
        return self.program

    def _effective_capacity(self, scale: float) -> int:
        """Integer capacity units a switch at ``scale`` still offers."""
        return int(np.floor(self.cfg.capacity * float(scale) + 1e-9))

    def on_switch_degrade(self, scales: dict[int, float]) -> ReduceProgram:
        """Partial aggregation-capacity loss: a(s) shrinks, not to zero.

        ``scales[s]`` is the remaining capacity fraction of switch ``s``
        relative to the *pristine* topology (like :meth:`on_link_degrade`
        semantics: 0.5 = half the aggregation plane left, 1.0 = fully
        recovered; the P4COM/SwitchAgg model where in-network compute is
        a gradually-lost resource). Values are validated — finite, in
        ``[0, 1]``, integral known switch ids — before any state mutates.

        Two-stage recovery, mirroring :meth:`on_switch_failure`:

        1. **degraded mode** — the *current* program is rebuilt instantly
           with no engine solve: the same blue set keeps aggregating at
           the reduced width, spilling its overflow one hop up
           (:func:`~repro.collectives.schedule.build_program` under
           ``cap_scale``), so the utilization regression is bounded by
           the overflow traffic. With a capacity ledger
           (``cfg.capacity``), a switch whose *effective* integer
           capacity ``floor(capacity * scale)`` drops below its live
           claims evicts claims — this workload's own blue first (it
           reverts to forwarding in the instant program), then foreign
           admissions (counted in the event record as
           ``evicted_foreign``); a scale of exactly 0 always forces blue
           off the switch, composing with the blocked/failed semantics.
        2. **replan** — fingerprint-keyed cache-or-solve (the
           fingerprint carries the capacity-scale vector, so restoring a
           previously-seen capacity state is a table lookup).

        Every event is recorded in ``degraded_events`` with the instant
        (degraded) and replanned utilization, the capacity delta, and
        any evictions.
        """
        n = self.topo0.tree.n
        items: list[tuple[int, float]] = []
        for s, f in scales.items():
            s = _switch_id(s, n)
            f = float(f)
            if not np.isfinite(f) or f < 0 or f > 1:
                raise ValueError(f"capacity scale for switch {s} must be "
                                 f"a finite fraction in [0, 1], got {f}")
            items.append((s, f))
        evicted_foreign = 0
        capacity_delta = 0
        dropped_own: list[int] = []
        if self._residual is not None:
            for s, f in items:
                eff_old = self._effective_capacity(self._switch_scale[s])
                eff_new = self._effective_capacity(f)
                capacity_delta += eff_new - eff_old
                claims = eff_old - int(self._residual[s])
                if claims > eff_new:
                    shortfall = claims - eff_new
                    if (shortfall and self.blue is not None
                            and self.blue[s]):
                        dropped_own.append(s)
                        shortfall -= 1
                        claims -= 1
                    evicted_foreign += shortfall
                    claims -= shortfall
                    # keep the job registry consistent with the ledger:
                    # the evicted foreign claims come off the youngest
                    # registered jobs holding s
                    if shortfall:
                        holders = sorted(
                            (j for j in self.jobs.values()
                             if j.tree == 0 and j.blue[s]),
                            key=lambda j: -j.order)
                        for j in holders[:shortfall]:
                            j.blue[s] = False
                self._residual[s] = eff_new - claims
        else:
            # unbounded capacity: only a dead plane (scale 0) forces the
            # workload's blue off — any positive scale still aggregates,
            # at reduced width
            dropped_own = [s for s, f in items
                           if f == 0.0 and self.blue is not None
                           and self.blue[s]]
        for s, f in items:
            self._switch_scale[s] = f
        self.topo = self._effective_topo()
        degraded_util = None
        if self.blue is not None:
            deg_blue = self.blue
            if dropped_own:
                deg_blue = self.blue.copy()
                deg_blue[dropped_own] = False
            # stage 1: instant bounded-regression program — no solve,
            # same (surviving) blues, overflow spilled to parents/hosts
            self.program = build_program(self.topo, deg_blue)
            self.blue = deg_blue
            degraded_util = self.program.utilization
        hit = self._recover()
        self.degraded_events.append({
            "switches": tuple(s for s, _ in items),
            "scales": tuple(f for _, f in items),
            "was_blue": tuple(dropped_own),
            "evicted_foreign": int(evicted_foreign),
            "capacity_delta": int(capacity_delta),
            "degraded_utilization": degraded_util,
            "utilization": self.program.utilization,
            "cache_hit": hit,
        })
        return self.program

    def on_link_degrade(self, rates: dict[int, float]) -> ReduceProgram:
        """Up-link rate changes: re-solve with the updated rho.

        ``rates[v]`` is the remaining rate fraction of switch ``v``'s
        up-link relative to the *pristine* topology (0.5 = half rate,
        1.0 = fully recovered) — the ``rho`` the placement DP optimizes
        over changes, so recovery runs through the normal engine path
        (cache-or-solve; restoring a previously-seen rate state is a
        lookup).
        """
        n = self.topo0.tree.n
        items = [(_switch_id(v, n), float(f)) for v, f in rates.items()]
        for v, f in items:
            if not np.isfinite(f) or f <= 0:
                raise ValueError(f"rate fraction for switch {v} must be a "
                                 f"positive finite number, got {f}")
        for v, f in items:
            self._link_rate[v] = f
        self.topo = self._effective_topo()
        self._recover()
        return self.program

    def on_step_durations(self, durations: np.ndarray) -> StragglerReport:
        """Feed per-device step durations; quarantine persistent stragglers.

        Dead and quarantined devices are masked out of the deadline
        quantile (their EWMA entries are stale and would skew the cutoff)
        and can never be suspects. Refuses to quarantine the last alive
        devices — the same ``n_alive`` floor :meth:`on_failure` enforces,
        but by skipping the quarantine rather than raising (step timings
        are advisory telemetry, not an operator command).
        """
        alive = self.alive & ~self.quarantined
        report = self.stragglers.observe(durations, alive=alive)
        newly = report.quarantined & ~self.quarantined & self.alive
        if newly.any() and int(newly.sum()) < self.n_alive:
            self.quarantined |= newly
            self.topo = self._effective_topo()
            self._recover()
        return report

    def on_recover(self, devices: list[int]) -> ReduceProgram:
        """A replaced/recovered chip rejoins the reduction tree.

        Only devices that are actually failed or quarantined can recover —
        symmetric with :meth:`on_failure`'s already-dead check. Validation
        runs before any state is touched, so a bad id in the middle of the
        list cannot leave a half-applied recovery.
        """
        for d in devices:
            if not 0 <= d < len(self.alive):
                raise ValueError(f"device {d} out of range "
                                 f"[0, {len(self.alive)})")
            if self.alive[d] and not self.quarantined[d]:
                raise ValueError(f"device {d} is not failed or quarantined")
        for d in devices:
            self.alive[d] = True
            self.quarantined[d] = False
            self.stragglers.clear(d)
        self.topo = self._effective_topo()
        self._recover()
        return self.program

    def on_rescale(self, n_pods: int | None = None,
                   racks_per_pod: int | None = None,
                   chips_per_rack: int | None = None,
                   budget_policy: str = "proportional") -> ReduceProgram:
        """Elastic rescale: drain -> rebuild the fleet -> re-sow the budget.

        The fleet tree is rebuilt at the new dimensions (unspecified ones
        keep their current value, see :func:`repro.runtime.elastic.
        rescale`), the blue budget moves per
        :func:`~repro.runtime.elastic.scaling_budget`, and this workload
        is re-placed through the normal claim accounting. Rescaling
        drains the fleet: other workloads' capacity claims are dropped
        (re-admit them via :meth:`begin_workloads`), and device health,
        straggler state and the preplan cache reset with the topology.
        """
        from .elastic import rescale, scaling_budget
        old_devices = self.topo0.n_devices
        new_topo = rescale(self.topo0, n_pods=n_pods,
                           racks_per_pod=racks_per_pod,
                           chips_per_rack=chips_per_rack)
        self.cfg = dataclasses.replace(
            self.cfg, k=scaling_budget(self.cfg.k, old_devices,
                                       new_topo.n_devices, budget_policy))
        n = new_topo.tree.n
        self.topo0 = new_topo
        self.topo = new_topo
        self.fleet = Fleet.single(new_topo)   # rescale drains fleet trees
        self._core_key = self.fleet.core_rho.tobytes()
        self.alive = np.ones(new_topo.n_devices, bool)
        self.quarantined = np.zeros(new_topo.n_devices, bool)
        self.switch_blocked = np.zeros(n, bool)
        self._link_rate = np.ones(n)
        self._switch_scale = np.ones(n)
        self._residual = (np.full(n, self.cfg.capacity, np.int64)
                          if self.cfg.capacity is not None else None)
        self._residuals = [self._residual]
        self.jobs.clear()             # rescale drains every foreign claim
        self._allred_util.clear()
        self._admission_cache.clear()
        self.stragglers = StragglerPolicy(
            new_topo.n_devices, quantile=self.cfg.straggler_quantile,
            slack=self.cfg.straggler_slack,
            patience=self.cfg.straggler_patience)
        self.blue = None
        self._topo_epoch += 1
        self._preplan.clear()
        self._replace()
        return self.program

    # -- multi-job admission --------------------------------------------------
    def _register_job(self, blue: np.ndarray, prog: ReduceProgram,
                      tree: int = 0, priority: int = 0) -> JobRecord:
        """File an admitted workload's claims in the job registry."""
        base = self._allred_util.get(tree)
        if base is None:
            tp = self.fleet.topos[tree]
            base = build_program(
                tp, np.zeros(tp.tree.n, bool)).utilization
            self._allred_util[tree] = base
        self._job_seq += 1
        rec = JobRecord(
            job_id=self._job_seq, tree=int(tree),
            blue=np.array(blue, dtype=bool, copy=True),
            priority=int(priority), order=self._job_seq,
            utilization=float(prog.utilization),
            benefit=float(base - prog.utilization))
        self.jobs[rec.job_id] = rec
        return rec

    def release_workloads(self, job_ids) -> int:
        """Release admitted jobs' capacity claims; returns claims freed."""
        freed = 0
        for jid in job_ids:
            j = self.jobs.pop(int(jid), None)
            if j is None:
                raise KeyError(f"unknown job id {jid}")
            self._residuals[j.tree][j.blue] += 1
            freed += int(j.blue.sum())
        return freed

    def _preempt(self, policy: PreemptionPolicy, res) -> tuple[list, int]:
        """Stage 1 of preemptive admission: evict registered jobs holding
        claims on the switches the failed wave exhausted (instant — no
        solve; the caller re-solves once against the freed ledger).
        Returns ``(victim job ids, claims freed on exhausted switches)``.
        """
        scarce = [np.asarray(ra) == 0 for ra in res.residual_after]
        shortfall = int(np.asarray(res.admission_dropped).sum())
        cands = [j for j in self.jobs.values()
                 if j.tree < len(scarce) and np.any(j.blue & scarce[j.tree])]
        victims: list[int] = []
        freed = 0
        for j in policy.order_victims(cands):
            if freed >= shortfall or len(victims) >= policy.max_victims:
                break
            self._residuals[j.tree][j.blue] += 1
            freed += int((j.blue & scarce[j.tree]).sum())
            victims.append(j.job_id)
            del self.jobs[j.job_id]
        return victims, freed

    def begin_workload(self, priority: int = 0) -> ReduceProgram:
        """Multi-workload mode (Sec. 5.2): claim capacity for a new workload.

        The previous workload keeps its claim; the new one sees only
        switches with residual capacity.
        """
        if self._residual is None:
            raise ValueError("begin_workload needs capacity set")
        blue, prog = plan(self.topo, self.cfg.k, avail=self._avail(),
                          strategy=self.cfg.strategy)
        self._residual[blue] -= 1
        self.utilization_history.append(prog.utilization)
        self._register_job(blue, prog, priority=priority)
        return prog

    def begin_workloads(self, count: int | None = None,
                        congestion_aware: bool = False,
                        capacity_priced: bool = False,
                        fleet: list[int] | None = None,
                        device_admission: bool = False,
                        preemption: PreemptionPolicy | None = None,
                        priority: int = 0,
                        **driver_kw) -> list[ReduceProgram]:
        """Admit ``count`` workloads with one batched engine solve.

        All instances are solved against the *current* availability
        snapshot in a single :func:`repro.engine.solve_batch` call; claims
        are then applied in order, and any workload whose placement
        touched a switch that ran out of capacity in the meantime is
        re-solved serially against the updated availability (rare — it
        needs ``count`` placements to pile onto one switch's last slots).

        ``congestion_aware=True`` routes admission through the
        repeated-solve congestion driver
        (:func:`repro.collectives.schedule.plan_congestion`): the batch is
        re-solved under penalty-reweighted link rates until the max-link
        congestion across the admitted tenants stops improving, then the
        same capacity claim/collision accounting applies. The driver's
        diagnostics land in ``self.last_congestion`` (re-measured against
        the *admitted* placements when collision fallbacks replaced any
        driver placement, so it never overstates the fleet); extra keyword
        arguments (``max_rounds``, ``alpha``, ``rho_weighted``,
        ``device_loop``, …) pass through to it. Requires
        ``strategy="soar"``.

        ``capacity_priced=True`` (congestion-aware only) additionally
        hands the driver the orchestrator's *residual capacity snapshot*
        as its capacity-pricing signal: switches this admission wave is
        about to exhaust get priced up inside the penalty loop, steering
        tenants away *before* the claim accounting collides — fewer
        serial collision fallbacks, same bounded-capacity guarantee.

        ``fleet=[c_0, .., c_{N-1}]`` (instead of ``count``) admits
        ``c_g`` workloads onto tree ``g`` of the orchestrator's
        :class:`~repro.collectives.topology.Fleet` with one *coupled*
        :func:`~repro.collectives.schedule.plan_fleet` solve — tenants on
        different trees trade placements through the fleet's shared core
        links — and per-tree capacity claims: each tenant claims against
        its own tree's residual ledger, collision fallbacks re-solve on
        the tenant's own tree only. Requires ``congestion_aware=True``
        (fleet admission *is* the congestion driver); a plain-topology
        orchestrator accepts ``fleet=[c]`` as the degenerate N=1 case.

        ``device_admission=True`` (congestion-aware only) moves the hard
        admission *inside* the device-resident penalty loop: the solver
        gets this orchestrator's residual ledger(s) as the engine's
        ``residual=`` constraint, so the returned placements are feasible
        wholesale — claims apply with **zero** collision fallbacks and
        zero extra host↔device round trips. When the wave still cannot
        fit (the loop reports dropped claims), a :class:`PreemptionPolicy`
        passed as ``preemption=`` evicts existing jobs from the exhausted
        switches (instantly, no solve) and re-solves once. Telemetry of
        every wave lands in ``self.last_admission``.
        """
        if self._residual is None:
            raise ValueError("begin_workloads needs capacity set")
        if congestion_aware and self.cfg.strategy != "soar":
            raise ValueError("congestion-aware admission needs "
                             f"strategy='soar', not {self.cfg.strategy!r}")
        if not congestion_aware and (driver_kw or capacity_priced
                                     or device_admission):
            what = (sorted(driver_kw) if driver_kw else
                    "device_admission" if device_admission
                    else "capacity_priced")
            raise ValueError(f"driver options {what} only "
                             "apply with congestion_aware=True")
        if preemption is not None and not device_admission:
            raise ValueError("preemption= needs device_admission=True — "
                             "only the in-loop admission path reports the "
                             "shortfall preemption resolves")
        if device_admission and "residual" in driver_kw:
            raise ValueError("device_admission=True supplies the "
                             "orchestrator's residual ledger; don't also "
                             "pass residual= explicitly")
        if (count is None) == (fleet is None):
            raise ValueError("pass exactly one of count / fleet")
        if fleet is not None:
            if not congestion_aware:
                raise ValueError("fleet admission is congestion-coupled; "
                                 "pass congestion_aware=True")
            return self._begin_fleet_workloads(
                [int(c) for c in fleet], capacity_priced, driver_kw,
                device_admission=device_admission, preemption=preemption,
                priority=priority)
        if capacity_priced:
            if "capacity" in driver_kw:
                raise ValueError("capacity_priced=True supplies the "
                                 "orchestrator's residual-capacity snapshot; "
                                 "don't also pass capacity= explicitly")
            driver_kw = dict(driver_kw,
                             capacity=self._residual.astype(np.float64))
        if count == 0:
            return []
        if device_admission:
            return self._begin_device_admission(count, preemption, priority,
                                                driver_kw)
        snapshot = self._avail()
        driver_res = None
        if congestion_aware:
            planned, driver_res = plan_congestion(
                self.topo, self.cfg.k, count=count, avails=snapshot,
                **driver_kw)
        else:
            planned = plan_batch([self.topo] * count, self.cfg.k,
                                 [snapshot] * count,
                                 strategy=self.cfg.strategy)
        progs: list[ReduceProgram] = []
        admitted: list[np.ndarray] = []
        collisions = 0
        for blue, prog in planned:
            if np.any(blue & (self._residual <= 0)):   # capacity collision
                blue, prog = plan(self.topo, self.cfg.k, avail=self._avail(),
                                  strategy=self.cfg.strategy)
                collisions += 1
            self._residual[blue] -= 1
            self.utilization_history.append(prog.utilization)
            self._register_job(blue, prog, priority=priority)
            progs.append(prog)
            admitted.append(blue)
        # each collision fallback is one extra host-side solve round trip
        # on top of the wave's batched solve
        self.last_admission = {
            "path": "host", "solves": 1 + collisions,
            "round_trips": 1 + collisions, "collisions": collisions,
            "dropped": 0, "preempted": (), "cache_hit": False}
        if driver_res is not None:
            # collision fallbacks replace driver placements with
            # utilization-only ones; re-measure so last_congestion reports
            # what was actually admitted, not what the driver proposed
            if collisions:
                from ..core.congestion import measure_fleet
                m = measure_fleet(
                    self.topo.tree, [self.topo.load] * count, admitted,
                    rho_weighted=driver_kw.get("rho_weighted", False))
                driver_res = dataclasses.replace(
                    driver_res, blue=np.stack(admitted), costs=m.costs,
                    msgs=m.msgs, congestion=m.congestion,
                    max_congestion=m.max_congestion,
                    mean_congestion=m.mean_congestion)
            self.last_congestion = driver_res
        return progs

    def _begin_device_admission(self, count: int,
                                preemption: PreemptionPolicy | None,
                                priority: int,
                                driver_kw: dict) -> list[ReduceProgram]:
        """Admission with the hard claim ledger *inside* the penalty loop.

        One coupled solve returns placements already feasible against
        ``self._residual`` — claims apply with zero collision fallbacks.
        A wave the ledger cannot fit triggers at most one preemption pass
        (policy-ordered evictions, then a single re-solve). Waves with no
        extra driver knobs and no preemption are served from the
        admission preplan cache when the exact (count, residual,
        fingerprint) state recurs — zero solves, zero round trips.
        """
        cacheable = not driver_kw and preemption is None
        key = ("admit", int(count), self._residual.tobytes(),
               self._fingerprint())
        if cacheable:
            entry = self._admission_cache.get(key)
            if entry is not None:
                progs = []
                for blue in entry["blues"]:
                    prog = build_program(self.topo, blue)
                    self._residual[blue] -= 1
                    self.utilization_history.append(prog.utilization)
                    self._register_job(blue, prog, priority=priority)
                    progs.append(prog)
                self.cache_recoveries += 1
                self.last_admission = {
                    "path": "device", "solves": 0, "round_trips": 0,
                    "collisions": 0, "dropped": 0, "preempted": (),
                    "cache_hit": True}
                return progs
        solves = 0
        victims: list[int] = []
        while True:
            planned, res = plan_congestion(
                self.topo, self.cfg.k, count=count, avails=self._avail(),
                residual=self._residual.copy(), **driver_kw)
            solves += 1
            dropped = int(np.asarray(res.admission_dropped).sum())
            if dropped == 0 or preemption is None or solves > 1:
                break
            evicted, freed = self._preempt(preemption, res)
            if not evicted:
                break
            victims.extend(evicted)
            self.preemption_events.append({
                "policy": preemption.kind, "victims": tuple(evicted),
                "freed": int(freed), "dropped_before": dropped})
        progs: list[ReduceProgram] = []
        for blue, prog in planned:
            self._residual[blue] -= 1
            self.utilization_history.append(prog.utilization)
            self._register_job(blue, prog, priority=priority)
            progs.append(prog)
        if np.any(self._residual < 0):
            raise RuntimeError("in-loop admission returned an infeasible "
                               "placement — engine/ledger disagreement")
        self.last_congestion = res
        self.last_admission = {
            "path": "device", "solves": solves, "round_trips": solves,
            "collisions": 0, "dropped": dropped,
            "preempted": tuple(victims), "cache_hit": False}
        if cacheable and dropped == 0 and not victims:
            self._admission_cache[key] = {
                "blues": [np.array(b, dtype=bool, copy=True)
                          for b, _ in planned]}
        return progs

    def _begin_fleet_workloads(self, counts: list[int],
                               capacity_priced: bool,
                               driver_kw: dict,
                               device_admission: bool = False,
                               preemption: PreemptionPolicy | None = None,
                               priority: int = 0) -> list[ReduceProgram]:
        """Fleet admission: one coupled solve, per-tree capacity claims."""
        N = self.fleet.n_trees
        if len(counts) != N or any(c < 1 for c in counts):
            raise ValueError(f"fleet counts must give >=1 workloads for "
                             f"each of the {N} trees, got {counts}")
        if capacity_priced:
            if "capacity" in driver_kw:
                raise ValueError("capacity_priced=True supplies the "
                                 "orchestrator's residual-capacity snapshot; "
                                 "don't also pass capacity= explicitly")
            driver_kw = dict(driver_kw, capacity=[
                r.astype(np.float64) for r in self._residuals])
        tree_of = [g for g, c in enumerate(counts) for _ in range(c)]
        if device_admission:
            return self._begin_fleet_device(counts, tree_of, preemption,
                                            priority, driver_kw)
        snaps = [r > 0 for r in self._residuals]
        planned, driver_res = plan_fleet(
            self.fleet, self.cfg.k, counts=counts,
            avails=[snaps[g] for g in tree_of], **driver_kw)
        progs: list[ReduceProgram] = []
        admitted: list[np.ndarray] = []
        collisions = 0
        for g, (blue, prog) in zip(tree_of, planned, strict=True):
            res_g = self._residuals[g]
            if np.any(blue & (res_g <= 0)):        # capacity collision
                blue, prog = plan(self.fleet.topos[g], self.cfg.k,
                                  avail=res_g > 0,
                                  strategy=self.cfg.strategy)
                collisions += 1
            res_g[blue] -= 1                       # this tree's ledger
            self.utilization_history.append(prog.utilization)
            self._register_job(blue, prog, tree=g, priority=priority)
            progs.append(prog)
            admitted.append(blue)
        self.last_admission = {
            "path": "host", "solves": 1 + collisions,
            "round_trips": 1 + collisions, "collisions": collisions,
            "dropped": 0, "preempted": (), "cache_hit": False}
        if collisions:
            # re-measure against the admitted placements (collision
            # fallbacks replaced driver ones) — global link-id space,
            # shared core included, so last_congestion never overstates
            from ..core.congestion import measure_fleet_multi
            trees = [tp.tree for tp in self.fleet.topos]
            loads = [self.fleet.topos[g].load for g in tree_of]
            has_core = self.fleet.n_core > 0
            m = measure_fleet_multi(
                trees, tree_of, loads, admitted,
                core_rho=self.fleet.core_rho if has_core else None,
                core_path=self.fleet.core_path if has_core else None,
                rho_weighted=driver_kw.get("rho_weighted", False))
            n_big = max(t.n for t in trees)
            stack = np.zeros((len(admitted), n_big), bool)
            for t, b in enumerate(admitted):
                stack[t, : b.size] = b
            driver_res = dataclasses.replace(
                driver_res, blue=stack, costs=m.costs, msgs=m.msgs,
                congestion=m.congestion, max_congestion=m.max_congestion,
                mean_congestion=m.mean_congestion,
                core_congestion=m.core_congestion)
        self.last_congestion = driver_res
        return progs

    def _begin_fleet_device(self, counts: list[int], tree_of: list[int],
                            preemption: PreemptionPolicy | None,
                            priority: int,
                            driver_kw: dict) -> list[ReduceProgram]:
        """Fleet admission with per-tree ledgers inside the loop — the
        multi-tree twin of :meth:`_begin_device_admission` (no collision
        fallbacks; at most one preemption pass)."""
        solves = 0
        victims: list[int] = []
        while True:
            snaps = [r > 0 for r in self._residuals]
            planned, res = plan_fleet(
                self.fleet, self.cfg.k, counts=counts,
                avails=[snaps[g] for g in tree_of],
                residual=[r.copy() for r in self._residuals], **driver_kw)
            solves += 1
            dropped = int(np.asarray(res.admission_dropped).sum())
            if dropped == 0 or preemption is None or solves > 1:
                break
            evicted, freed = self._preempt(preemption, res)
            if not evicted:
                break
            victims.extend(evicted)
            self.preemption_events.append({
                "policy": preemption.kind, "victims": tuple(evicted),
                "freed": int(freed), "dropped_before": dropped})
        progs: list[ReduceProgram] = []
        for g, (blue, prog) in zip(tree_of, planned, strict=True):
            self._residuals[g][blue] -= 1
            self.utilization_history.append(prog.utilization)
            self._register_job(blue, prog, tree=g, priority=priority)
            progs.append(prog)
        if any(np.any(r < 0) for r in self._residuals):
            raise RuntimeError("in-loop fleet admission returned an "
                               "infeasible placement — engine/ledger "
                               "disagreement")
        self.last_congestion = res
        self.last_admission = {
            "path": "device", "solves": solves, "round_trips": solves,
            "collisions": 0, "dropped": dropped,
            "preempted": tuple(victims), "cache_hit": False}
        return progs

    # -- telemetry ------------------------------------------------------------
    def preplan_cache_stats(self) -> dict:
        """Preplan-cache telemetry: lookup hits / misses / stale entries,
        current entry count, and recoveries served without a solve."""
        return {**self._preplan_stats, "entries": len(self._preplan),
                "cache_recoveries": self.cache_recoveries}

    def engine_cache_stats(self) -> dict:
        """Placement-engine compile/packing cache telemetry.

        Batched replanning (``begin_workloads`` / ``preplan_failures``)
        leans on the engine's jit cache: the layout-bucketed Forest
        packing maps the orchestrator's recurring scenario shapes onto a
        handful of compiled executables. Surface the counters so
        operators can verify steady-state serving isn't recompiling. The
        ``preplan`` sub-dict reports the recovery preplan cache
        (:meth:`preplan_cache_stats`) next to them.
        """
        from ..engine import cache_stats
        return {**cache_stats(), "preplan": self.preplan_cache_stats()}

    # -- what-if preplanning --------------------------------------------------
    def preplan_failures(
        self, failure_sets: list[list[int]]
    ) -> list[tuple[np.ndarray, float]]:
        """What-if analysis: SOAR placements for hypothetical failures.

        Builds the effective topology of every scenario and solves them
        all in one batched engine call (same tree shape -> one compiled
        executable; the device-resident solve returns just the masks and
        costs). Returns ``[(blue, utilization)]`` per scenario, and files
        every result in the preplan cache so the matching *real* failure
        recovers with a table lookup instead of a solve (entries go stale
        — and fall back to solving — if the capacity landscape shifts
        before the failure happens).
        """
        topos, fps = [], []
        for devices in failure_sets:
            dead = set(np.nonzero(~self.alive | self.quarantined)[0].tolist())
            dead.update(int(d) for d in devices)
            dead = sorted(dead)
            topos.append(self._scenario_topo(dead))
            fps.append(self._fingerprint(dead=tuple(dead)))
        # a real failure replan releases this workload's own claim before
        # re-placing; mirror that, or preplans would see fewer available
        # switches than recovery actually has
        avail = self._replan_avail()
        planned = plan_batch(topos, self.cfg.k, [avail] * len(topos),
                             strategy=self.cfg.strategy)
        out = []
        for fp, (blue, prog) in zip(fps, planned):
            self._preplan_store(fp, blue, prog.utilization, avail)
            out.append((blue, prog.utilization))
        return out

    def preplan_link_degrades(
        self, rate_sets: list[dict[int, float]] | None = None,
        factor: float = 0.5,
    ) -> list[tuple[np.ndarray, float]]:
        """What-if analysis for link-rate degradations.

        By default preplans every currently-undegraded switch's up-link
        dropping to ``factor`` of its pristine rate, alone — the
        single-link brownouts that dominate real degradation traffic —
        in one batched engine call; pass explicit ``rate_sets`` (each a
        ``{switch: fraction}`` dict, fractions relative to the pristine
        topology like :meth:`on_link_degrade`) for correlated scenarios.
        Results are returned as ``[(blue, utilization)]`` and filed in
        the preplan cache keyed by the post-degrade fingerprint (link
        rates are already part of every key), so the matching real
        :meth:`on_link_degrade` recovers with a table lookup instead of
        a solve — bit-identical to what a fresh solve would place, and
        subject to the same capacity-drift staleness eviction as
        :meth:`preplan_failures` / :meth:`preplan_switch_failures`.
        """
        n = self.topo0.tree.n
        if rate_sets is None:
            if not np.isfinite(factor) or not 0 < factor:
                raise ValueError(f"rate fraction must be a positive finite "
                                 f"number, got {factor}")
            rate_sets = [{int(v): float(factor)} for v in range(n)
                         if self._link_rate[v] == 1.0]
        dead_now = sorted(
            np.nonzero(~self.alive | self.quarantined)[0].tolist())
        topos, fps = [], []
        for rates in rate_sets:
            items = [(int(v), float(f)) for v, f in rates.items()]
            for v, f in items:
                if not 0 <= v < n:
                    raise ValueError(f"switch {v} out of range [0, {n})")
                if not np.isfinite(f) or f <= 0:
                    raise ValueError(f"rate fraction for switch {v} must "
                                     f"be a positive finite number, got {f}")
            lr = self._link_rate.copy()
            for v, f in items:
                lr[v] = f
            topos.append(self._scenario_topo(dead_now, link_rate=lr))
            fps.append(self._fingerprint(link_rate=lr))
        avail = self._replan_avail()
        planned = plan_batch(topos, self.cfg.k, [avail] * len(topos),
                             strategy=self.cfg.strategy)
        out = []
        for fp, (blue, prog) in zip(fps, planned):
            self._preplan_store(fp, blue, prog.utilization, avail)
            out.append((blue, prog.utilization))
        return out

    def preplan_switch_failures(
        self, switch_sets: list[list[int]] | None = None
    ) -> list[tuple[np.ndarray, float]]:
        """What-if analysis for aggregation-plane failures.

        By default preplans every currently-available switch failing
        alone — the single-switch scenarios that dominate real recovery
        traffic — in one batched engine call; pass explicit ``switch_sets``
        for correlated scenarios. Results are returned as
        ``[(blue, utilization)]`` and filed in the preplan cache keyed by
        the post-failure topology fingerprint, so
        :meth:`on_switch_failure` recovers those scenarios without a
        solve (staleness rules as in :meth:`preplan_failures`).
        """
        n = self.topo0.tree.n
        if switch_sets is None:
            switch_sets = [[int(s)]
                           for s in np.nonzero(~self.switch_blocked)[0]]
        dead_now = sorted(
            np.nonzero(~self.alive | self.quarantined)[0].tolist())
        base = self._scenario_topo(dead_now)
        topos, fps = [], []
        for switches in switch_sets:
            blocked = self.switch_blocked.copy()
            for s in switches:
                s = int(s)
                if not 0 <= s < n:
                    raise ValueError(f"switch {s} out of range [0, {n})")
                blocked[s] = True
            topos.append(dataclasses.replace(base, blocked=blocked))
            fps.append(self._fingerprint(
                blocked=tuple(np.nonzero(blocked)[0].tolist())))
        avail = self._replan_avail()
        planned = plan_batch(topos, self.cfg.k, [avail] * len(topos),
                             strategy=self.cfg.strategy)
        out = []
        for fp, (blue, prog) in zip(fps, planned):
            self._preplan_store(fp, blue, prog.utilization, avail)
            out.append((blue, prog.utilization))
        return out
