"""Fault-tolerant orchestration: failures/stragglers -> SOAR re-placement.

The orchestrator owns the cluster reduction tree, the current blue
placement, and the compiled-in ReduceProgram. Every topology event (device
failure, straggler quarantine, elastic rescale) triggers the same recovery
path the paper's model makes cheap:

    update tree/load -> SOAR re-sow (O(n h k^2), milliseconds at fleet
    scale) -> rebuild the static reduction program -> resume.

Recovery is *bounded*: the budget k and per-switch aggregation capacity
(Sec. 5.2) are respected across re-placements, so a tenant can never grab
more in-network resources by failing chips.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..collectives.schedule import (ReduceProgram, build_program, plan,
                                    plan_batch, plan_congestion)
from ..collectives.topology import ClusterTopology, fail_devices
from .stragglers import StragglerPolicy, StragglerReport


@dataclasses.dataclass
class OrchestratorConfig:
    k: int = 4                       # blue-switch budget for this workload
    strategy: str = "soar"           # placement strategy (soar | baselines)
    capacity: int | None = None      # per-switch aggregation capacity a(s)
    straggler_quantile: float = 0.9
    straggler_slack: float = 2.0
    straggler_patience: int = 3


class Orchestrator:
    """Owns topology -> placement -> program; replans on events."""

    def __init__(self, topo: ClusterTopology, cfg: OrchestratorConfig):
        self.cfg = cfg
        self.topo0 = topo
        self.topo = topo
        n = topo.tree.n
        self.alive = np.ones(topo.n_devices, bool)
        self.quarantined = np.zeros(topo.n_devices, bool)
        # residual aggregation capacity (None = unbounded)
        self._residual = (np.full(n, cfg.capacity, np.int64)
                          if cfg.capacity is not None else None)
        self.stragglers = StragglerPolicy(
            topo.n_devices, quantile=cfg.straggler_quantile,
            slack=cfg.straggler_slack, patience=cfg.straggler_patience)
        self.replans = 0
        self.utilization_history: list[float] = []
        self.blue: np.ndarray | None = None
        self.program: ReduceProgram | None = None
        self.last_congestion = None   # CongestionResult of the most recent
                                      # congestion-aware admission
        self._replace()

    # -- properties ----------------------------------------------------------
    @property
    def n_alive(self) -> int:
        return int((self.alive & ~self.quarantined).sum())

    @property
    def grad_scale(self) -> float:
        """Gradient renormalization: mean over contributing devices."""
        return self.topo0.n_devices / max(1, self.n_alive)

    # -- internal ------------------------------------------------------------
    def _avail(self) -> np.ndarray | None:
        if self._residual is None:
            return None
        return self._residual > 0

    def _replace(self) -> None:
        """(Re)compute the SOAR placement + reduction program."""
        if self._residual is not None and self.blue is not None:
            self._residual[self.blue] += 1  # release the old claim
        self.blue, self.program = plan(
            self.topo, self.cfg.k, avail=self._avail(),
            strategy=self.cfg.strategy)
        if self._residual is not None:
            self._residual[self.blue] -= 1
        self.replans += 1
        self.utilization_history.append(self.program.utilization)

    def _effective_topo(self) -> ClusterTopology:
        dead = np.nonzero(~self.alive | self.quarantined)[0]
        return fail_devices(self.topo0, list(dead))

    # -- event handlers -------------------------------------------------------
    def on_failure(self, devices: list[int]) -> ReduceProgram:
        """Hard failure: chips stop producing gradient messages.

        Validates every id before touching any state (and collapses
        duplicates), so a bad id mid-list cannot leave the orchestrator
        half-applied — same discipline as :meth:`on_recover` and
        :func:`~repro.collectives.topology.fail_devices`.
        """
        devices = list(dict.fromkeys(int(d) for d in devices))
        for d in devices:
            if not 0 <= d < len(self.alive):
                raise ValueError(f"device {d} out of range "
                                 f"[0, {len(self.alive)})")
            if not self.alive[d]:
                raise ValueError(f"device {d} already dead")
        # quarantined devices don't count towards n_alive, so only the
        # non-quarantined failures reduce it — reject before mutating
        if sum(1 for d in devices if not self.quarantined[d]) >= self.n_alive:
            raise RuntimeError("all devices failed")
        for d in devices:
            self.alive[d] = False
        self.topo = self._effective_topo()
        self._replace()
        return self.program

    def on_step_durations(self, durations: np.ndarray) -> StragglerReport:
        """Feed per-device step durations; quarantine persistent stragglers."""
        report = self.stragglers.observe(durations)
        newly = report.quarantined & ~self.quarantined & self.alive
        if newly.any():
            self.quarantined |= newly
            self.topo = self._effective_topo()
            self._replace()
        return report

    def on_recover(self, devices: list[int]) -> ReduceProgram:
        """A replaced/recovered chip rejoins the reduction tree.

        Only devices that are actually failed or quarantined can recover —
        symmetric with :meth:`on_failure`'s already-dead check. Validation
        runs before any state is touched, so a bad id in the middle of the
        list cannot leave a half-applied recovery.
        """
        for d in devices:
            if not 0 <= d < len(self.alive):
                raise ValueError(f"device {d} out of range "
                                 f"[0, {len(self.alive)})")
            if self.alive[d] and not self.quarantined[d]:
                raise ValueError(f"device {d} is not failed or quarantined")
        for d in devices:
            self.alive[d] = True
            self.quarantined[d] = False
            self.stragglers.clear(d)
        self.topo = self._effective_topo()
        self._replace()
        return self.program

    def begin_workload(self) -> ReduceProgram:
        """Multi-workload mode (Sec. 5.2): claim capacity for a new workload.

        The previous workload keeps its claim; the new one sees only
        switches with residual capacity.
        """
        if self._residual is None:
            raise ValueError("begin_workload needs capacity set")
        blue, prog = plan(self.topo, self.cfg.k, avail=self._avail(),
                          strategy=self.cfg.strategy)
        self._residual[blue] -= 1
        self.utilization_history.append(prog.utilization)
        return prog

    def begin_workloads(self, count: int, congestion_aware: bool = False,
                        capacity_priced: bool = False,
                        **driver_kw) -> list[ReduceProgram]:
        """Admit ``count`` workloads with one batched engine solve.

        All instances are solved against the *current* availability
        snapshot in a single :func:`repro.engine.solve_batch` call; claims
        are then applied in order, and any workload whose placement
        touched a switch that ran out of capacity in the meantime is
        re-solved serially against the updated availability (rare — it
        needs ``count`` placements to pile onto one switch's last slots).

        ``congestion_aware=True`` routes admission through the
        repeated-solve congestion driver
        (:func:`repro.collectives.schedule.plan_congestion`): the batch is
        re-solved under penalty-reweighted link rates until the max-link
        congestion across the admitted tenants stops improving, then the
        same capacity claim/collision accounting applies. The driver's
        diagnostics land in ``self.last_congestion`` (re-measured against
        the *admitted* placements when collision fallbacks replaced any
        driver placement, so it never overstates the fleet); extra keyword
        arguments (``max_rounds``, ``alpha``, ``rho_weighted``,
        ``device_loop``, …) pass through to it. Requires
        ``strategy="soar"``.

        ``capacity_priced=True`` (congestion-aware only) additionally
        hands the driver the orchestrator's *residual capacity snapshot*
        as its capacity-pricing signal: switches this admission wave is
        about to exhaust get priced up inside the penalty loop, steering
        tenants away *before* the claim accounting collides — fewer
        serial collision fallbacks, same bounded-capacity guarantee.
        """
        if self._residual is None:
            raise ValueError("begin_workloads needs capacity set")
        if congestion_aware and self.cfg.strategy != "soar":
            raise ValueError("congestion-aware admission needs "
                             f"strategy='soar', not {self.cfg.strategy!r}")
        if not congestion_aware and (driver_kw or capacity_priced):
            what = sorted(driver_kw) if driver_kw else "capacity_priced"
            raise ValueError(f"driver options {what} only "
                             "apply with congestion_aware=True")
        if capacity_priced:
            if "capacity" in driver_kw:
                raise ValueError("capacity_priced=True supplies the "
                                 "orchestrator's residual-capacity snapshot; "
                                 "don't also pass capacity= explicitly")
            driver_kw = dict(driver_kw,
                             capacity=self._residual.astype(np.float64))
        if count == 0:
            return []
        snapshot = self._avail()
        driver_res = None
        if congestion_aware:
            planned, driver_res = plan_congestion(
                self.topo, self.cfg.k, count=count, avails=snapshot,
                **driver_kw)
        else:
            planned = plan_batch([self.topo] * count, self.cfg.k,
                                 [snapshot] * count,
                                 strategy=self.cfg.strategy)
        progs: list[ReduceProgram] = []
        admitted: list[np.ndarray] = []
        collisions = 0
        for blue, prog in planned:
            if np.any(blue & (self._residual <= 0)):   # capacity collision
                blue, prog = plan(self.topo, self.cfg.k, avail=self._avail(),
                                  strategy=self.cfg.strategy)
                collisions += 1
            self._residual[blue] -= 1
            self.utilization_history.append(prog.utilization)
            progs.append(prog)
            admitted.append(blue)
        if driver_res is not None:
            # collision fallbacks replace driver placements with
            # utilization-only ones; re-measure so last_congestion reports
            # what was actually admitted, not what the driver proposed
            if collisions:
                from ..core.congestion import measure_fleet
                m = measure_fleet(
                    self.topo.tree, [self.topo.load] * count, admitted,
                    rho_weighted=driver_kw.get("rho_weighted", False))
                driver_res = dataclasses.replace(
                    driver_res, blue=np.stack(admitted), costs=m.costs,
                    msgs=m.msgs, congestion=m.congestion,
                    max_congestion=m.max_congestion,
                    mean_congestion=m.mean_congestion)
            self.last_congestion = driver_res
        return progs

    def engine_cache_stats(self) -> dict:
        """Placement-engine compile/packing cache telemetry.

        Batched replanning (``begin_workloads`` / ``preplan_failures``)
        leans on the engine's jit cache: the layout-bucketed Forest
        packing maps the orchestrator's recurring scenario shapes onto a
        handful of compiled executables. Surface the counters so
        operators can verify steady-state serving isn't recompiling.
        """
        from ..engine import cache_stats
        return cache_stats()

    def preplan_failures(
        self, failure_sets: list[list[int]]
    ) -> list[tuple[np.ndarray, float]]:
        """What-if analysis: SOAR placements for hypothetical failures.

        Builds the effective topology of every scenario and solves them
        all in one batched engine call (same tree shape -> one compiled
        executable; the device-resident solve returns just the masks and
        costs). Returns ``[(blue, utilization)]`` per scenario; the
        orchestrator can stash these to make real recovery a table lookup.
        """
        topos = []
        for devices in failure_sets:
            dead = set(np.nonzero(~self.alive | self.quarantined)[0].tolist())
            dead.update(int(d) for d in devices)
            topos.append(fail_devices(self.topo0, sorted(dead)))
        # a real failure replan releases this workload's own claim before
        # re-placing (_replace); mirror that, or preplans would see fewer
        # available switches than recovery actually has
        if self._residual is not None and self.blue is not None:
            residual = self._residual.copy()
            residual[self.blue] += 1
            avail = residual > 0
        else:
            avail = self._avail()
        planned = plan_batch(topos, self.cfg.k, [avail] * len(topos),
                             strategy=self.cfg.strategy)
        return [(blue, prog.utilization) for blue, prog in planned]
