"""Deterministic chaos harness for the fault-tolerant orchestrator.

Reliability claims about the recovery path are only as good as the event
sequences they were tested under. This module makes those sequences
*reproducible*: :func:`generate_scenario` derives a feasibility-checked
event stream from a seed (device/switch/link faults, straggler storms,
correlated rack failures, recoveries, link-degrade preplanning that later
degrade events replay against the cache, optional multi-workload
admissions),
and :class:`ChaosHarness` steps an :class:`~repro.runtime.Orchestrator`
through it, re-checking the system's safety invariants after *every*
event:

  * the blue budget is respected and no blue sits on a blocked switch;
  * per-switch capacity residuals never go negative, and the claim
    ledger balances (capacity handed out == blue claims live);
  * the installed program's utilization equals ``phi`` recomputed from
    the current topology and mask — the program is never stale;
  * whenever a recovery was served from the preplan cache, a fresh
    engine solve of the same scenario must reproduce the cached
    placement bit-for-bit (the cache can be fast, never wrong);
  * the fleet keeps a quorum of healthy devices.

A violated invariant raises :class:`InvariantViolation` naming the event
and the failed check, so a chaos run doubles as a regression bisection
tool: replay the same seed, stop at the same event.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..collectives.schedule import plan
from ..core.reduce import phi
from .orchestrator import Orchestrator, OrchestratorConfig

KINDS = ("fail_device", "recover_device", "fail_switch", "recover_switch",
         "degrade_link", "recover_link", "straggler_storm",
         "recover_quarantined", "fail_rack", "admit_workloads",
         "preplan_links")

DEGRADE_FACTORS = (0.5, 0.25, 0.125)


class InvariantViolation(AssertionError):
    """A safety invariant failed after a chaos event."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected event. Only the fields its ``kind`` uses are set."""
    kind: str
    devices: tuple = ()       # fail/recover_device, storm slow set, rack
    switches: tuple = ()      # fail/recover_switch, rack switch
    rates: tuple = ()         # degrade/recover_link: ((switch, fraction),)
    steps: int = 0            # straggler_storm: observed steps
    slow: float = 8.0         # straggler_storm: slow-device duration
    count: int = 0            # admit_workloads


@dataclasses.dataclass
class ChaosReport:
    """What a chaos run did and what it cost."""
    records: list             # per-event dicts (kind, util, cache_hit, ...)
    events: int
    replans: int              # engine solves the orchestrator performed
    cache_hits: int           # recoveries served by the preplan cache
    stale: int                # cache entries evicted for capacity drift
    invariant_checks: int
    seconds: float

    @property
    def events_per_sec(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0


def _storm_limit(n_alive: int, quantile: float) -> int:
    """Max slow devices a storm may have while still guaranteeing the
    deadline quantile stays at the fast-device level (linear-interpolation
    quantile: index q*(H-1) must not reach the m slow order statistics)."""
    return int(np.floor((n_alive - 1) * (1.0 - quantile)))


def generate_scenario(topo, n_events: int = 50, seed: int = 0,
                      cfg: OrchestratorConfig | None = None,
                      admits: bool = False,
                      min_healthy: int | None = None) -> list[FaultEvent]:
    """Derive a deterministic, feasibility-checked event sequence.

    Mirrors the orchestrator's health state (failed / quarantined devices,
    blocked switches, degraded links) while sampling, so every emitted
    event is valid when it arrives: no double-failures, the fleet never
    drops below ``min_healthy`` live devices (default ``max(2, n/4)``), at
    most half the switches are ever blocked, and straggler storms are
    sized so the deadline math *guarantees* the slow devices get
    quarantined (slow count <= ``(alive-1) * (1-quantile)``, exactly
    ``patience`` observed steps). The same ``(topo, n_events, seed, cfg)``
    always yields the same list.
    """
    cfg = cfg or OrchestratorConfig()
    rng = np.random.default_rng(seed)
    n_dev = topo.n_devices
    n_sw = topo.tree.n
    if min_healthy is None:
        min_healthy = max(2, n_dev // 4)
    racks: dict[int, list[int]] = {}
    for dev, leaf in enumerate(topo.device_leaf):
        racks.setdefault(int(leaf), []).append(dev)

    failed: set[int] = set()
    quarantined: set[int] = set()
    blocked: set[int] = set()
    degraded: dict[int, float] = {}
    # link-degrade what-ifs the stream has preplanned; later degrade_link
    # events preferentially replay them, exercising the cache-served
    # recovery path (preplan_link_degrades -> on_link_degrade lookup)
    preplanned_links: list[tuple[int, float]] = []

    def healthy() -> list[int]:
        return [d for d in range(n_dev)
                if d not in failed and d not in quarantined]

    events: list[FaultEvent] = []
    while len(events) < n_events:
        alive = healthy()
        menu: list[tuple[str, float]] = []
        if len(alive) - 1 >= min_healthy:
            menu.append(("fail_device", 3.0))
        if failed:
            menu.append(("recover_device", 3.0))
        if len(blocked) + 1 <= n_sw // 2:
            menu.append(("fail_switch", 2.0))
        if blocked:
            menu.append(("recover_switch", 2.0))
        menu.append(("degrade_link", 2.0))
        if degraded:
            menu.append(("recover_link", 2.0))
        if len(degraded) < n_sw:
            menu.append(("preplan_links", 1.0))
        storm_cap = min(_storm_limit(len(alive), cfg.straggler_quantile),
                        len(alive) - min_healthy)
        if storm_cap >= 1:
            menu.append(("straggler_storm", 1.0))
        if quarantined:
            menu.append(("recover_quarantined", 1.0))
        rack_ok = [r for r, devs in racks.items()
                   if r not in blocked
                   and len(blocked) + 1 <= n_sw // 2
                   and any(d in alive for d in devs)
                   and len(alive) - sum(d in alive for d in devs)
                   >= min_healthy]
        if rack_ok:
            menu.append(("fail_rack", 1.0))
        if admits:
            menu.append(("admit_workloads", 1.0))

        kinds = [k for k, _ in menu]
        w = np.asarray([w for _, w in menu])
        kind = str(rng.choice(kinds, p=w / w.sum()))

        if kind == "fail_device":
            m = int(rng.integers(1, min(2, len(alive) - min_healthy) + 1))
            devs = rng.choice(alive, size=m, replace=False)
            failed.update(int(d) for d in devs)
            events.append(FaultEvent("fail_device",
                                     devices=tuple(sorted(int(d)
                                                          for d in devs))))
        elif kind == "recover_device":
            m = int(rng.integers(1, min(2, len(failed)) + 1))
            devs = rng.choice(sorted(failed), size=m, replace=False)
            failed.difference_update(int(d) for d in devs)
            events.append(FaultEvent("recover_device",
                                     devices=tuple(sorted(int(d)
                                                          for d in devs))))
        elif kind == "fail_switch":
            s = int(rng.choice([v for v in range(n_sw) if v not in blocked]))
            blocked.add(s)
            events.append(FaultEvent("fail_switch", switches=(s,)))
        elif kind == "recover_switch":
            s = int(rng.choice(sorted(blocked)))
            blocked.discard(s)
            events.append(FaultEvent("recover_switch", switches=(s,)))
        elif kind == "degrade_link":
            # half the time replay a preplanned what-if (when one is still
            # applicable): its fingerprint matches iff no other link state
            # changed since the preplan, so the stream exercises both the
            # cache-hit and the honest-miss recovery paths
            usable = [(v, f) for v, f in preplanned_links
                      if v not in degraded]
            if usable and rng.random() < 0.5:
                v, f = usable[int(rng.integers(len(usable)))]
            else:
                v = int(rng.integers(0, n_sw))
                f = float(rng.choice(DEGRADE_FACTORS))
            degraded[v] = f
            events.append(FaultEvent("degrade_link", rates=((v, f),)))
        elif kind == "recover_link":
            v = int(rng.choice(sorted(degraded)))
            del degraded[v]
            events.append(FaultEvent("recover_link", rates=((v, 1.0),)))
        elif kind == "straggler_storm":
            m = int(rng.integers(1, storm_cap + 1))
            devs = rng.choice(alive, size=m, replace=False)
            quarantined.update(int(d) for d in devs)
            events.append(FaultEvent(
                "straggler_storm",
                devices=tuple(sorted(int(d) for d in devs)),
                steps=cfg.straggler_patience, slow=8.0))
        elif kind == "recover_quarantined":
            quarantined.clear()
            events.append(FaultEvent("recover_quarantined"))
        elif kind == "fail_rack":
            r = int(rng.choice(rack_ok))
            devs = tuple(sorted(d for d in racks[r] if d in alive))
            failed.update(devs)
            blocked.add(r)
            events.append(FaultEvent("fail_rack", devices=devs,
                                     switches=(r,)))
        elif kind == "preplan_links":
            cand = [v for v in range(n_sw) if v not in degraded]
            m = int(rng.integers(1, min(3, len(cand)) + 1))
            vs = rng.choice(cand, size=m, replace=False)
            pairs = tuple(
                (int(v), float(rng.choice(DEGRADE_FACTORS)))
                for v in sorted(int(v) for v in vs))
            preplanned_links.extend(pairs)
            events.append(FaultEvent("preplan_links", rates=pairs))
        else:  # admit_workloads
            events.append(FaultEvent("admit_workloads",
                                     count=int(rng.integers(1, 3))))
    return events


class ChaosHarness:
    """Steps an orchestrator through fault events, checking invariants.

    ``verify_cache_hits=True`` (the default, and the expensive part) runs
    a fresh engine solve after every cache-served recovery and requires
    the placement to match the cached one bit-for-bit.
    """

    def __init__(self, orch: Orchestrator, verify_cache_hits: bool = True):
        self.orch = orch
        self.verify_cache_hits = verify_cache_hits
        self.invariant_checks = 0
        # the observable capacity ledger: whatever is unclaimed now plus
        # this workload's own claim. Extra admissions are tracked as they
        # happen so the balance stays checkable.
        if orch._residual is not None:
            self._capacity_total = int(orch._residual.sum()
                                       + int(orch.blue.sum()))
        else:
            self._capacity_total = None
        self._extra_claims = 0

    # -- event dispatch -------------------------------------------------------
    def step(self, ev: FaultEvent) -> dict:
        """Apply one event, then re-check every invariant."""
        o = self.orch
        hits0 = o._preplan_stats["hits"]
        if ev.kind == "fail_device":
            o.on_failure(list(ev.devices))
        elif ev.kind == "recover_device":
            o.on_recover(list(ev.devices))
        elif ev.kind == "fail_switch":
            o.on_switch_failure(list(ev.switches))
        elif ev.kind == "recover_switch":
            o.on_switch_recover(list(ev.switches))
        elif ev.kind in ("degrade_link", "recover_link"):
            o.on_link_degrade(dict(ev.rates))
        elif ev.kind == "straggler_storm":
            durations = np.ones(o.topo0.n_devices)
            durations[list(ev.devices)] = ev.slow
            for _ in range(ev.steps):
                o.on_step_durations(durations)
        elif ev.kind == "recover_quarantined":
            quarantined = np.nonzero(o.quarantined)[0].tolist()
            if quarantined:                       # no-op if nothing is held
                o.on_recover(quarantined)
        elif ev.kind == "fail_rack":
            # correlated fault domain: the rack's chips die with the
            # rack switch's aggregation plane
            o.on_failure(list(ev.devices))
            o.on_switch_failure(list(ev.switches))
        elif ev.kind == "preplan_links":
            # one single-link what-if per preplanned pair: the matching
            # real degrade_link later in the stream becomes a cache lookup
            o.preplan_link_degrades([{v: f} for v, f in ev.rates])
        elif ev.kind == "admit_workloads":
            before = int(o._residual.sum())
            o.begin_workloads(ev.count)
            self._extra_claims += before - int(o._residual.sum())
        else:
            raise ValueError(f"unknown event kind {ev.kind!r}")
        cache_hit = o._preplan_stats["hits"] > hits0
        self.check_invariants(cache_hit=cache_hit, event=ev)
        return {
            "kind": ev.kind,
            "utilization": o.program.utilization,
            "cache_hit": cache_hit,
            "n_alive": o.n_alive,
            "replans": o.replans,
        }

    # -- invariants -----------------------------------------------------------
    def check_invariants(self, cache_hit: bool = False,
                         event: FaultEvent | None = None) -> None:
        o = self.orch
        where = f" after {event.kind} {event!r}" if event else ""

        def _require(ok: bool, msg: str) -> None:
            if not ok:
                raise InvariantViolation(msg + where)

        _require(o.n_alive > 0, "no healthy devices left")
        _require(int(o.blue.sum()) <= o.cfg.k,
                 f"blue count {int(o.blue.sum())} exceeds budget {o.cfg.k}")
        _require(not np.any(o.blue & o.switch_blocked),
                 "blue placement on a blocked switch")
        if o._residual is not None:
            _require(bool((o._residual >= 0).all()),
                     f"negative capacity residual "
                     f"{o._residual.min()} at switch "
                     f"{int(o._residual.argmin())}")
            handed_out = self._capacity_total - int(o._residual.sum())
            _require(handed_out == int(o.blue.sum()) + self._extra_claims,
                     f"claim ledger imbalance: {handed_out} capacity "
                     f"claimed vs {int(o.blue.sum())} blue + "
                     f"{self._extra_claims} admitted")
        fresh_util = phi(o.topo.tree, o.topo.load, o.blue)
        _require(o.program.utilization == fresh_util,
                 f"program utilization {o.program.utilization} != "
                 f"phi of current placement {fresh_util}")
        if cache_hit and self.verify_cache_hits:
            blue, prog = plan(o.topo, o.cfg.k, avail=o._replan_avail(),
                              strategy=o.cfg.strategy)
            _require(bool(np.array_equal(blue, o.blue)),
                     "cache-served placement differs from a fresh solve")
            _require(prog.utilization == o.program.utilization,
                     f"cache-served utilization {o.program.utilization} != "
                     f"fresh solve {prog.utilization}")
        self.invariant_checks += 1

    # -- driver ---------------------------------------------------------------
    def run(self, events: list[FaultEvent]) -> ChaosReport:
        """Step through all events; returns the run's report."""
        o = self.orch
        replans0, hits0 = o.replans, o._preplan_stats["hits"]
        t0 = time.perf_counter()
        records = [self.step(ev) for ev in events]
        dt = time.perf_counter() - t0
        return ChaosReport(
            records=records,
            events=len(events),
            replans=o.replans - replans0,
            cache_hits=o._preplan_stats["hits"] - hits0,
            stale=o._preplan_stats["stale"],
            invariant_checks=self.invariant_checks,
            seconds=dt,
        )
