"""Deterministic chaos harness for the fault-tolerant orchestrator.

Reliability claims about the recovery path are only as good as the event
sequences they were tested under. This module makes those sequences
*reproducible*: :func:`generate_scenario` derives a feasibility-checked
event stream from a seed (device/switch/link faults, straggler storms,
correlated rack failures, recoveries, link-degrade preplanning that later
degrade events replay against the cache, optional multi-workload
admissions — including device-side hard-admission waves, preemptive
admissions under a :class:`~repro.runtime.PreemptionPolicy`, and job
releases),
and :class:`ChaosHarness` steps an :class:`~repro.runtime.Orchestrator`
through it, re-checking the system's safety invariants after *every*
event:

  * the blue budget is respected and no blue sits on a blocked switch;
  * per-switch capacity residuals never go negative, the claim
    ledger balances (capacity handed out == blue claims live), and
    every tree's residual plus its registered job claims reconstructs
    the effective per-switch capacity exactly;
  * the installed program's utilization equals ``phi_degraded``
    recomputed from the current topology, mask, and per-switch capacity
    scales — the program is never stale, and never aggregates on a
    zero-capacity plane;
  * whenever a recovery was served from the preplan cache, a fresh
    engine solve of the same scenario must reproduce the cached
    placement bit-for-bit (the cache can be fast, never wrong);
  * the fleet keeps a quorum of healthy devices.

A violated invariant raises :class:`InvariantViolation` naming the event
and the failed check, so a chaos run doubles as a regression bisection
tool: replay the same seed, stop at the same event.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..collectives.schedule import build_program, plan
from ..core.reduce import phi_degraded
from .orchestrator import Orchestrator, OrchestratorConfig, PreemptionPolicy

KINDS = ("fail_device", "recover_device", "fail_switch", "recover_switch",
         "degrade_link", "recover_link", "straggler_storm",
         "recover_quarantined", "fail_rack", "admit_workloads",
         "preplan_links", "degrade_switch", "recover_switch_capacity",
         "crash", "admit_jobs", "preempt_admit", "release_jobs")

#: preemption policies preempt_admit events cycle through
POLICIES = PreemptionPolicy.KINDS

DEGRADE_FACTORS = (0.5, 0.25, 0.125)
# partial aggregation-capacity loss fractions for degrade_switch events
CAP_FRACS = (0.75, 0.5, 0.25)


class InvariantViolation(AssertionError):
    """A safety invariant failed after a chaos event."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected event. Only the fields its ``kind`` uses are set."""
    kind: str
    devices: tuple = ()       # fail/recover_device, storm slow set, rack
    switches: tuple = ()      # fail/recover_switch, rack switch
    rates: tuple = ()         # degrade/recover_link: ((switch, fraction),)
    steps: int = 0            # straggler_storm: observed steps
    slow: float = 8.0         # straggler_storm: slow-device duration
    count: int = 0            # admit_workloads / admit_jobs / release_jobs
    policy: str = ""          # preempt_admit: PreemptionPolicy kind


@dataclasses.dataclass
class ChaosReport:
    """What a chaos run did and what it cost."""
    records: list             # per-event dicts (kind, util, cache_hit, ...)
    events: int
    replans: int              # engine solves the orchestrator performed
    cache_hits: int           # recoveries served by the preplan cache
    stale: int                # cache entries evicted for capacity drift
    invariant_checks: int
    seconds: float
    train: dict | None = None  # ChaosTrainer summary when training-coupled

    @property
    def events_per_sec(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0


def _storm_limit(n_alive: int, quantile: float) -> int:
    """Max slow devices a storm may have while still guaranteeing the
    deadline quantile stays at the fast-device level (linear-interpolation
    quantile: index q*(H-1) must not reach the m slow order statistics)."""
    return int(np.floor((n_alive - 1) * (1.0 - quantile)))


def generate_scenario(topo, n_events: int = 50, seed: int = 0,
                      cfg: OrchestratorConfig | None = None,
                      admits: bool = False,
                      min_healthy: int | None = None,
                      train: bool = False) -> list[FaultEvent]:
    """Derive a deterministic, feasibility-checked event sequence.

    Mirrors the orchestrator's health state (failed / quarantined devices,
    blocked switches, degraded links, partially-degraded aggregation
    planes) while sampling, so every emitted event is valid when it
    arrives: no double-failures, the fleet never drops below
    ``min_healthy`` live devices (default ``max(2, n/4)``), at most half
    the switches are ever blocked, and straggler storms are sized so the
    deadline math *guarantees* the slow devices get quarantined (slow
    count <= ``(alive-1) * (1-quantile)``, exactly ``patience`` observed
    steps). ``train=True`` additionally mixes in ``crash`` events —
    process loss that only a :class:`ChaosTrainer` (checkpoint restart)
    can absorb. The same ``(topo, n_events, seed, cfg, train)`` always
    yields the same list.
    """
    cfg = cfg or OrchestratorConfig()
    rng = np.random.default_rng(seed)
    n_dev = topo.n_devices
    n_sw = topo.tree.n
    if min_healthy is None:
        min_healthy = max(2, n_dev // 4)
    racks: dict[int, list[int]] = {}
    for dev, leaf in enumerate(topo.device_leaf):
        racks.setdefault(int(leaf), []).append(dev)

    failed: set[int] = set()
    quarantined: set[int] = set()
    blocked: set[int] = set()
    live_jobs = 0   # mirrored registry size (upper bound; release is lenient)
    degraded: dict[int, float] = {}
    cap_degraded: dict[int, float] = {}   # partially-degraded agg planes
    # link-degrade what-ifs the stream has preplanned; later degrade_link
    # events preferentially replay them, exercising the cache-served
    # recovery path (preplan_link_degrades -> on_link_degrade lookup)
    preplanned_links: list[tuple[int, float]] = []

    def healthy() -> list[int]:
        return [d for d in range(n_dev)
                if d not in failed and d not in quarantined]

    events: list[FaultEvent] = []
    while len(events) < n_events:
        alive = healthy()
        menu: list[tuple[str, float]] = []
        if len(alive) - 1 >= min_healthy:
            menu.append(("fail_device", 3.0))
        if failed:
            menu.append(("recover_device", 3.0))
        if len(blocked) + 1 <= n_sw // 2:
            menu.append(("fail_switch", 2.0))
        if blocked:
            menu.append(("recover_switch", 2.0))
        menu.append(("degrade_link", 2.0))
        if degraded:
            menu.append(("recover_link", 2.0))
        if len(degraded) < n_sw:
            menu.append(("preplan_links", 1.0))
        cap_ok = [v for v in range(n_sw)
                  if v not in cap_degraded and v not in blocked]
        if cap_ok:
            menu.append(("degrade_switch", 2.0))
        if cap_degraded:
            menu.append(("recover_switch_capacity", 2.0))
        if train:
            menu.append(("crash", 0.5))
        storm_cap = min(_storm_limit(len(alive), cfg.straggler_quantile),
                        len(alive) - min_healthy)
        if storm_cap >= 1:
            menu.append(("straggler_storm", 1.0))
        if quarantined:
            menu.append(("recover_quarantined", 1.0))
        rack_ok = [r for r, devs in racks.items()
                   if r not in blocked
                   and len(blocked) + 1 <= n_sw // 2
                   and any(d in alive for d in devs)
                   and len(alive) - sum(d in alive for d in devs)
                   >= min_healthy]
        if rack_ok:
            menu.append(("fail_rack", 1.0))
        if admits:
            menu.append(("admit_workloads", 1.0))
            menu.append(("admit_jobs", 1.0))
            if live_jobs:
                menu.append(("preempt_admit", 1.0))
                menu.append(("release_jobs", 1.0))

        kinds = [k for k, _ in menu]
        w = np.asarray([w for _, w in menu])
        kind = str(rng.choice(kinds, p=w / w.sum()))

        if kind == "fail_device":
            m = int(rng.integers(1, min(2, len(alive) - min_healthy) + 1))
            devs = rng.choice(alive, size=m, replace=False)
            failed.update(int(d) for d in devs)
            events.append(FaultEvent("fail_device",
                                     devices=tuple(sorted(int(d)
                                                          for d in devs))))
        elif kind == "recover_device":
            m = int(rng.integers(1, min(2, len(failed)) + 1))
            devs = rng.choice(sorted(failed), size=m, replace=False)
            failed.difference_update(int(d) for d in devs)
            events.append(FaultEvent("recover_device",
                                     devices=tuple(sorted(int(d)
                                                          for d in devs))))
        elif kind == "fail_switch":
            s = int(rng.choice([v for v in range(n_sw) if v not in blocked]))
            blocked.add(s)
            events.append(FaultEvent("fail_switch", switches=(s,)))
        elif kind == "recover_switch":
            s = int(rng.choice(sorted(blocked)))
            blocked.discard(s)
            events.append(FaultEvent("recover_switch", switches=(s,)))
        elif kind == "degrade_link":
            # half the time replay a preplanned what-if (when one is still
            # applicable): its fingerprint matches iff no other link state
            # changed since the preplan, so the stream exercises both the
            # cache-hit and the honest-miss recovery paths
            usable = [(v, f) for v, f in preplanned_links
                      if v not in degraded]
            if usable and rng.random() < 0.5:
                v, f = usable[int(rng.integers(len(usable)))]
            else:
                v = int(rng.integers(0, n_sw))
                f = float(rng.choice(DEGRADE_FACTORS))
            degraded[v] = f
            events.append(FaultEvent("degrade_link", rates=((v, f),)))
        elif kind == "recover_link":
            v = int(rng.choice(sorted(degraded)))
            del degraded[v]
            events.append(FaultEvent("recover_link", rates=((v, 1.0),)))
        elif kind == "degrade_switch":
            s = int(rng.choice(cap_ok))
            f = float(rng.choice(CAP_FRACS))
            cap_degraded[s] = f
            events.append(FaultEvent("degrade_switch", rates=((s, f),)))
        elif kind == "recover_switch_capacity":
            s = int(rng.choice(sorted(cap_degraded)))
            del cap_degraded[s]
            events.append(FaultEvent("recover_switch_capacity",
                                     rates=((s, 1.0),)))
        elif kind == "crash":
            events.append(FaultEvent("crash"))
        elif kind == "straggler_storm":
            m = int(rng.integers(1, storm_cap + 1))
            devs = rng.choice(alive, size=m, replace=False)
            quarantined.update(int(d) for d in devs)
            events.append(FaultEvent(
                "straggler_storm",
                devices=tuple(sorted(int(d) for d in devs)),
                steps=cfg.straggler_patience, slow=8.0))
        elif kind == "recover_quarantined":
            quarantined.clear()
            events.append(FaultEvent("recover_quarantined"))
        elif kind == "fail_rack":
            r = int(rng.choice(rack_ok))
            devs = tuple(sorted(d for d in racks[r] if d in alive))
            failed.update(devs)
            blocked.add(r)
            events.append(FaultEvent("fail_rack", devices=devs,
                                     switches=(r,)))
        elif kind == "preplan_links":
            cand = [v for v in range(n_sw) if v not in degraded]
            m = int(rng.integers(1, min(3, len(cand)) + 1))
            vs = rng.choice(cand, size=m, replace=False)
            pairs = tuple(
                (int(v), float(rng.choice(DEGRADE_FACTORS)))
                for v in sorted(int(v) for v in vs))
            preplanned_links.extend(pairs)
            events.append(FaultEvent("preplan_links", rates=pairs))
        elif kind == "admit_jobs":
            c = int(rng.integers(1, 3))
            live_jobs += c
            events.append(FaultEvent("admit_jobs", count=c))
        elif kind == "preempt_admit":
            c = int(rng.integers(1, 3))
            live_jobs += c          # admitted wave joins the registry
            events.append(FaultEvent("preempt_admit", count=c,
                                     policy=str(rng.choice(POLICIES))))
        elif kind == "release_jobs":
            c = int(rng.integers(1, 3))
            live_jobs = max(0, live_jobs - c)
            events.append(FaultEvent("release_jobs", count=c))
        else:  # admit_workloads
            c = int(rng.integers(1, 3))
            live_jobs += c
            events.append(FaultEvent("admit_workloads", count=c))
    return events


class ChaosHarness:
    """Steps an orchestrator through fault events, checking invariants.

    ``verify_cache_hits=True`` (the default, and the expensive part) runs
    a fresh engine solve after every cache-served recovery and requires
    the placement to match the cached one bit-for-bit.

    Pass a :class:`ChaosTrainer` as ``trainer`` to drive a *real*
    training step after every event (training-coupled chaos): events
    that neither removed a contributing device nor moved the blue
    placement are **lossless** and the step's result must be bit-identical
    to the fault-free program's — the executor's degraded-mode spill is
    exact, not approximate. ``crash`` events restart the trainer from
    its latest checkpoint; without a trainer they are no-ops.
    """

    def __init__(self, orch: Orchestrator, verify_cache_hits: bool = True,
                 trainer: "ChaosTrainer | None" = None):
        self.orch = orch
        self.verify_cache_hits = verify_cache_hits
        self.trainer = trainer
        self.invariant_checks = 0
        # the observable capacity ledger: whatever is unclaimed now plus
        # this workload's own claim. Extra admissions are tracked as they
        # happen so the balance stays checkable.
        if orch._residual is not None:
            self._capacity_total = int(orch._residual.sum()
                                       + int(orch.blue.sum()))
        else:
            self._capacity_total = None
        self._extra_claims = 0

    # -- event dispatch -------------------------------------------------------
    def step(self, ev: FaultEvent) -> dict:
        """Apply one event, then re-check every invariant."""
        o = self.orch
        hits0 = o._preplan_stats["hits"]
        pre_contrib = (o.alive & ~o.quarantined).copy()
        pre_blue = None if o.blue is None else o.blue.copy()
        if ev.kind == "fail_device":
            o.on_failure(list(ev.devices))
        elif ev.kind == "recover_device":
            o.on_recover(list(ev.devices))
        elif ev.kind == "fail_switch":
            o.on_switch_failure(list(ev.switches))
        elif ev.kind == "recover_switch":
            o.on_switch_recover(list(ev.switches))
        elif ev.kind in ("degrade_link", "recover_link"):
            o.on_link_degrade(dict(ev.rates))
        elif ev.kind == "straggler_storm":
            durations = np.ones(o.topo0.n_devices)
            durations[list(ev.devices)] = ev.slow
            for _ in range(ev.steps):
                o.on_step_durations(durations)
        elif ev.kind == "recover_quarantined":
            quarantined = np.nonzero(o.quarantined)[0].tolist()
            if quarantined:                       # no-op if nothing is held
                o.on_recover(quarantined)
        elif ev.kind == "fail_rack":
            # correlated fault domain: the rack's chips die with the
            # rack switch's aggregation plane
            o.on_failure(list(ev.devices))
            o.on_switch_failure(list(ev.switches))
        elif ev.kind == "preplan_links":
            # one single-link what-if per preplanned pair: the matching
            # real degrade_link later in the stream becomes a cache lookup
            o.preplan_link_degrades([{v: f} for v, f in ev.rates])
        elif ev.kind == "admit_workloads":
            before = int(o._residual.sum())
            o.begin_workloads(ev.count)
            self._extra_claims += before - int(o._residual.sum())
        elif ev.kind in ("admit_jobs", "preempt_admit"):
            # hard admission inside the device penalty loop; preempt_admit
            # additionally arms a preemption policy so a wave that cannot
            # fit evicts victims instead of failing
            before = int(o._residual.sum())
            policy = (PreemptionPolicy(kind=ev.policy or "priority")
                      if ev.kind == "preempt_admit" else None)
            o.begin_workloads(ev.count, congestion_aware=True,
                              device_admission=True, preemption=policy,
                              max_rounds=2)
            self._extra_claims += before - int(o._residual.sum())
        elif ev.kind == "release_jobs":
            ids = sorted(o.jobs)[:ev.count]
            if ids:
                before = int(o._residual.sum())
                o.release_workloads(ids)
                self._extra_claims += before - int(o._residual.sum())
        elif ev.kind in ("degrade_switch", "recover_switch_capacity"):
            o.on_switch_degrade(dict(ev.rates))
            rec = o.degraded_events[-1]
            if self._capacity_total is not None:
                # the observable capacity pool shrank/grew with the plane,
                # and evicted foreign claims leave the admitted ledger
                self._capacity_total += rec["capacity_delta"]
                self._extra_claims -= rec["evicted_foreign"]
        elif ev.kind == "crash":
            pass  # orchestrator state survives; the trainer restarts below
        else:
            raise ValueError(f"unknown event kind {ev.kind!r}")
        cache_hit = o._preplan_stats["hits"] > hits0
        self.check_invariants(cache_hit=cache_hit, event=ev)
        record = {
            "kind": ev.kind,
            "utilization": o.program.utilization,
            "cache_hit": cache_hit,
            "n_alive": o.n_alive,
            "replans": o.replans,
        }
        if self.trainer is not None:
            lossless = (ev.kind != "crash" and pre_blue is not None
                        and o.blue is not None
                        and np.array_equal(pre_contrib,
                                           o.alive & ~o.quarantined)
                        and np.array_equal(pre_blue, o.blue))
            record.update(self.trainer.after_event(ev, lossless=lossless))
        return record

    # -- invariants -----------------------------------------------------------
    def check_invariants(self, cache_hit: bool = False,
                         event: FaultEvent | None = None) -> None:
        o = self.orch
        where = f" after {event.kind} {event!r}" if event else ""

        def _require(ok: bool, msg: str) -> None:
            if not ok:
                raise InvariantViolation(msg + where)

        _require(o.n_alive > 0, "no healthy devices left")
        _require(int(o.blue.sum()) <= o.cfg.k,
                 f"blue count {int(o.blue.sum())} exceeds budget {o.cfg.k}")
        _require(not np.any(o.blue & o.switch_blocked),
                 "blue placement on a blocked switch")
        if o.topo.cap_scale is not None:
            _require(not np.any(o.blue & (o.topo.cap_scale <= 0)),
                     "blue placement on a zero-capacity switch")
        if o._residual is not None:
            _require(bool((o._residual >= 0).all()),
                     f"negative capacity residual "
                     f"{o._residual.min()} at switch "
                     f"{int(o._residual.argmin())}")
            handed_out = self._capacity_total - int(o._residual.sum())
            _require(handed_out == int(o.blue.sum()) + self._extra_claims,
                     f"claim ledger imbalance: {handed_out} capacity "
                     f"claimed vs {int(o.blue.sum())} blue + "
                     f"{self._extra_claims} admitted")
            # per-switch conservation: each tree's residual plus the job
            # registry's claims against it (and the orchestrator's own
            # blue on tree 0) must reconstruct the effective capacity of
            # every switch exactly — no claim leaks, no double-frees
            eff0 = np.asarray([o._effective_capacity(sc)
                               for sc in o._switch_scale], np.int64)
            for g, res_g in enumerate(o._residuals):
                if res_g is None:
                    continue
                total = res_g.astype(np.int64, copy=True)
                for j in o.jobs.values():
                    if j.tree == g:
                        total += j.blue.astype(np.int64)
                if g == 0:
                    total += o.blue.astype(np.int64)
                    eff = eff0
                else:
                    eff = np.full(res_g.shape[0], o.cfg.capacity,
                                  np.int64)
                if not np.array_equal(total, eff):
                    s = int(np.nonzero(total != eff)[0][0])
                    _require(False,
                             f"per-switch claim conservation broken on "
                             f"tree {g} switch {s}: residual+claims "
                             f"{int(total[s])} != effective capacity "
                             f"{int(eff[s])}")
        fresh_util = phi_degraded(o.topo.tree, o.topo.load, o.blue,
                                  o.topo.cap_scale)
        _require(o.program.utilization == fresh_util,
                 f"program utilization {o.program.utilization} != "
                 f"phi of current placement {fresh_util}")
        if cache_hit and self.verify_cache_hits:
            blue, prog = plan(o.topo, o.cfg.k, avail=o._replan_avail(),
                              strategy=o.cfg.strategy)
            _require(bool(np.array_equal(blue, o.blue)),
                     "cache-served placement differs from a fresh solve")
            _require(prog.utilization == o.program.utilization,
                     f"cache-served utilization {o.program.utilization} != "
                     f"fresh solve {prog.utilization}")
        self.invariant_checks += 1

    # -- driver ---------------------------------------------------------------
    def run(self, events: list[FaultEvent]) -> ChaosReport:
        """Step through all events; returns the run's report."""
        o = self.orch
        replans0, hits0 = o.replans, o._preplan_stats["hits"]
        t0 = time.perf_counter()
        records = [self.step(ev) for ev in events]
        dt = time.perf_counter() - t0
        return ChaosReport(
            records=records,
            events=len(events),
            replans=o.replans - replans0,
            cache_hits=o._preplan_stats["hits"] - hits0,
            stale=o._preplan_stats["stale"],
            invariant_checks=self.invariant_checks,
            seconds=dt,
            train=None if self.trainer is None else self.trainer.summary(),
        )


class ChaosTrainer:
    """Real training steps interleaved with chaos events.

    Couples the chaos harness to the end-to-end driver: a tiny model
    trains with the orchestrator's *live* SOAR reduction program, one
    step per event, so recovery claims are checked against actual
    gradient arithmetic rather than cost accounting alone:

      * **lossless events** (no contributing device lost, blue placement
        unchanged — e.g. partial capacity degrades, link degrades) must
        leave the step *bit-identical* to the fault-free program's: the
        step runs twice from the same state, once under the installed
        (possibly degraded/spilling) program and once under the pristine
        ``cap_scale=None`` program, and every parameter, optimizer slot
        and the loss must match bitwise (the strict-left-fold spill
        construction is exact, not approximate);
      * **crash events** restart from the latest checkpoint, asserting
        the restored state is bitwise what was saved, and rewinding the
        step counter — the unrecoverable-event path.

    JAX is imported lazily (constructing a trainer is opt-in; the rest
    of this module stays importable without it). The orchestrator must
    be built over a topology whose device count matches
    ``jax.device_count()`` — use :func:`repro.launch.train.dp_fleet`.
    Step functions are cached by (load, blue, cap-scale, grad-scale), so
    revisited program states pay no recompile; per-step wall times are
    recorded with a ``compiled`` flag so throughput stats can exclude
    compile steps.
    """

    def __init__(self, orch: Orchestrator, arch: str = "qwen3-32b",
                 seq: int = 32, global_batch: int | None = None,
                 ckpt_dir: str | None = None, ckpt_every: int = 5,
                 seed: int = 0):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..checkpoint import ckpt as _ckpt
        from ..configs import ARCHS
        from ..data.pipeline import DataConfig, SyntheticLM
        from ..models import api
        from ..optim import adamw
        from ..optim.compression import (CompressionConfig,
                                         init_error_feedback)

        self.orch = orch
        n_dev = orch.topo0.n_devices
        if n_dev != jax.device_count():
            raise ValueError(
                f"orchestrator topology has {n_dev} devices but JAX sees "
                f"{jax.device_count()}; build the orchestrator over "
                f"dp_fleet(jax.device_count())")
        self.n_dev = n_dev
        self.cfg = ARCHS[arch].reduced()
        self.ocfg = adamw.AdamWConfig()
        self.ccfg = CompressionConfig()
        self.mesh = jax.make_mesh((n_dev,), ("data",))
        self.global_batch = global_batch or max(4, n_dev)
        if self.global_batch % n_dev:
            raise ValueError(f"global_batch {self.global_batch} not "
                             f"divisible by {n_dev} devices")
        self.seq = seq
        self.data = SyntheticLM(self.cfg,
                                DataConfig(self.global_batch, seq,
                                           seed=seed))
        self.params = api.init_fn(self.cfg)(jax.random.PRNGKey(seed))
        self.opt_state = adamw.init(self.params, self.ocfg)
        if n_dev > 1:
            ef = jax.tree.map(
                lambda p: jnp.zeros((n_dev,) + p.shape, jnp.float32),
                self.params)
            self.ef = jax.device_put(ef, NamedSharding(self.mesh,
                                                       P("data")))
            self._batch_sharding = NamedSharding(self.mesh, P("data"))
        else:
            self.ef = init_error_feedback(self.params)
            self._batch_sharding = None
        self.step_no = 0
        self.steps_run = 0      # executed steps; unlike step_no, never rewinds
        self.losses: list[float] = []
        self.step_times: list[tuple[float, bool]] = []  # (secs, compiled)
        self.bitwise_checks = 0
        self.restores = 0
        self._step_fns: dict[tuple, object] = {}
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every)
        self._ckpt = _ckpt
        self._saved: dict | None = None
        if ckpt_dir is not None:
            # synchronous saves: a crash may arrive on the very next event
            self.mgr = _ckpt.CheckpointManager(ckpt_dir, async_save=False)
            self._save()
        else:
            self.mgr = None

    # -- checkpointing --------------------------------------------------------
    def _state(self) -> dict:
        return {"params": self.params, "opt": self.opt_state}

    def _save(self) -> None:
        import jax
        self.mgr.save(self.step_no, self._state())
        self._saved = {"step": self.step_no,
                       "state": jax.device_get(self._state())}

    def crash_restore(self) -> None:
        """Process loss: rebuild training state from the latest checkpoint.

        Asserts the restored pytree is *bitwise* the one that was saved
        (checkpoint integrity), then rewinds the step counter so the data
        pipeline replays the same batches.
        """
        if self.mgr is None:
            raise InvariantViolation(
                "crash event without a checkpoint directory")
        state, step = self._ckpt.restore(self.ckpt_dir, self._state())
        if self._saved is not None:
            _assert_trees_bitwise(
                state, self._saved["state"],
                what=f"checkpoint restore at step {step}")
            if step != self._saved["step"]:
                raise InvariantViolation(
                    f"restored step {step} != last saved "
                    f"{self._saved['step']}")
        self.params, self.opt_state = state["params"], state["opt"]
        self.step_no = int(step)
        del self.losses[self.step_no:]
        self.restores += 1

    # -- stepping -------------------------------------------------------------
    def _step_fn(self, program, grad_scale: float, pristine: bool = False):
        """make_step, cached by everything the compiled fn closes over.

        ``pristine`` marks the fault-free reference program (built with
        ``cap_scale=None``); when no degrade is active it shares the
        live program's cache entry, so the bitwise check costs no extra
        compile.
        """
        o = self.orch
        scale_key = (b"" if pristine or o.topo.cap_scale is None
                     else np.asarray(o.topo.cap_scale).tobytes())
        key = (o.topo.load.tobytes(),
               b"" if o.blue is None else o.blue.tobytes(),
               scale_key, float(grad_scale))
        fresh = key not in self._step_fns
        if fresh:
            from ..launch.train import make_step
            self._step_fns[key] = make_step(self.cfg, self.ocfg, self.mesh,
                                            program, grad_scale, self.ccfg)
        return self._step_fns[key], fresh

    def _run(self, fn, batch):
        import jax
        out = fn(self.params, self.opt_state, self.ef, batch)
        jax.block_until_ready(out)
        return out

    def train_step(self, check_bitwise: bool = False) -> dict:
        """One optimizer step with the orchestrator's current program.

        With ``check_bitwise`` the same state also steps through the
        fault-free (``cap_scale=None``) program and the two results must
        agree bit-for-bit.
        """
        import jax
        from ..launch.train import mask_dead_batch

        o = self.orch
        batch = self.data.batch(self.step_no)
        if self.n_dev > 1:
            batch = jax.tree.map(
                lambda x: jax.device_put(x, self._batch_sharding), batch)
            batch = mask_dead_batch(batch, o.alive & ~o.quarantined,
                                    self.global_batch, self.n_dev)
        fn, fresh = self._step_fn(o.program, o.grad_scale)
        if check_bitwise:
            ref_prog = build_program(
                dataclasses.replace(o.topo, cap_scale=None), o.blue)
            ref_fn, ref_fresh = self._step_fn(ref_prog, o.grad_scale,
                                              pristine=True)
            fresh = fresh or ref_fresh
            ref = self._run(ref_fn, batch)
        t0 = time.perf_counter()
        out = self._run(fn, batch)
        dt = time.perf_counter() - t0
        params, opt_state, ef, metrics = out
        if check_bitwise:
            _assert_trees_bitwise(
                {"params": params, "opt": opt_state,
                 "loss": metrics["loss"]},
                {"params": ref[0], "opt": ref[1], "loss": ref[3]["loss"]},
                what=f"lossless step {self.step_no} vs fault-free program")
            self.bitwise_checks += 1
        self.params, self.opt_state, self.ef = params, opt_state, ef
        loss = float(metrics["loss"])
        self.losses.append(loss)
        self.step_times.append((dt, fresh))
        self.step_no += 1
        self.steps_run += 1
        if self.mgr is not None and self.step_no % self.ckpt_every == 0:
            self._save()
        return {"loss": loss, "step": self.step_no,
                "step_seconds": dt, "compiled": fresh,
                "bitwise_checked": bool(check_bitwise)}

    def after_event(self, ev: FaultEvent, lossless: bool = False) -> dict:
        """Harness hook: absorb the event, then take one training step."""
        if ev.kind == "crash":
            self.crash_restore()
            info = self.train_step(check_bitwise=False)
            info["restored"] = True
            return info
        return self.train_step(check_bitwise=lossless)

    def summary(self) -> dict:
        times = [t for t, compiled in self.step_times if not compiled]
        return {
            "steps": self.steps_run,
            "first_loss": self.losses[0] if self.losses else None,
            "last_loss": self.losses[-1] if self.losses else None,
            "bitwise_checks": self.bitwise_checks,
            "restores": self.restores,
            "compiles": sum(1 for _, c in self.step_times if c),
            "median_step_seconds": (float(np.median(times)) if times
                                    else None),
        }


def _assert_trees_bitwise(got, want, what: str) -> None:
    """Raise InvariantViolation unless two pytrees match bit-for-bit."""
    import jax

    got_l, got_t = jax.tree.flatten(jax.device_get(got))
    want_l, want_t = jax.tree.flatten(jax.device_get(want))
    if got_t != want_t:
        raise InvariantViolation(f"{what}: tree structure differs")
    for i, (a, b) in enumerate(zip(got_l, want_l)):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape or a.dtype != b.dtype or \
                a.tobytes() != b.tobytes():
            raise InvariantViolation(
                f"{what}: leaf {i} differs "
                f"(shape {a.shape} dtype {a.dtype}; max abs diff "
                f"{np.max(np.abs(a.astype(np.float64) - b.astype(np.float64))) if a.shape == b.shape else 'n/a'})")
