"""Elastic scaling of the reduction fleet.

``rescale`` rebuilds the cluster topology at a new size and maps the SOAR
budget onto it. Shrinks reuse the failure path (drop chips, zero load);
grows re-derive the fleet tree. The parameter/optimizer state itself is
re-sharded through the checkpoint layer (``checkpoint.restore`` accepts any
target sharding — save on the old mesh, restore on the new one), so elastic
events are: drain -> checkpoint -> rescale topology -> re-place blue nodes
-> restore -> resume.
"""
from __future__ import annotations

import numpy as np

from ..collectives.topology import ClusterTopology, fail_devices, fleet_tree


def rescale(topo: ClusterTopology, n_pods: int, racks_per_pod: int,
            chips_per_rack: int) -> ClusterTopology:
    """Return a fresh fleet tree at the new size (grow or shrink)."""
    return fleet_tree(n_pods=n_pods, racks_per_pod=racks_per_pod,
                      chips_per_rack=chips_per_rack)


def shrink_by_failure(topo: ClusterTopology, dead: list[int]) -> ClusterTopology:
    """In-place shrink: keep the tree, drop the dead chips' load."""
    return fail_devices(topo, dead)


def scaling_budget(k: int, old_devices: int, new_devices: int,
                   policy: str = "proportional") -> int:
    """How the blue budget moves when the fleet is rescaled.

    proportional: k scales with device count (NaaS per-tenant contract);
    fixed: the tenant bought k switches, size changes don't alter it.
    """
    if policy == "fixed":
        return k
    if policy == "proportional":
        return max(1, round(k * new_devices / max(1, old_devices)))
    raise ValueError(f"unknown budget policy {policy!r}")
