"""Elastic scaling of the reduction fleet.

``rescale`` rebuilds the cluster topology at a new size and maps the SOAR
budget onto it. Shrinks reuse the failure path (drop chips, zero load);
grows re-derive the fleet tree. The parameter/optimizer state itself is
re-sharded through the checkpoint layer (``checkpoint.restore`` accepts any
target sharding — save on the old mesh, restore on the new one), so elastic
events are: drain -> checkpoint -> rescale topology -> re-place blue nodes
-> restore -> resume.
"""
from __future__ import annotations

import numpy as np

from ..collectives.topology import ClusterTopology, fail_devices, fleet_tree


def fleet_dims(topo: ClusterTopology) -> tuple[int, int, int]:
    """Derive ``(n_pods, racks_per_pod, chips_per_rack)`` from a
    fleet-shaped topology (root spine -> pods -> racks[-> chip leaves]).

    Works for both :func:`~repro.collectives.topology.fleet_tree` and
    :func:`~repro.collectives.topology.chip_level_tree` outputs; raises on
    topologies that are not pod/rack regular.
    """
    t = topo.tree
    pods = t.children[t.root]
    if not pods:
        raise ValueError("not a fleet-shaped topology: root has no pods")
    n_pods = len(pods)
    racks_per_pod = len(t.children[pods[0]])
    if racks_per_pod == 0 or any(len(t.children[p]) != racks_per_pod
                                 for p in pods):
        raise ValueError("not a fleet-shaped topology: ragged pods")
    n_racks = n_pods * racks_per_pod
    if topo.n_devices == 0 or topo.n_devices % n_racks:
        raise ValueError("not a fleet-shaped topology: ragged racks")
    return n_pods, racks_per_pod, topo.n_devices // n_racks


def rescale(topo: ClusterTopology, n_pods: int | None = None,
            racks_per_pod: int | None = None,
            chips_per_rack: int | None = None) -> ClusterTopology:
    """Return a fresh fleet tree at the new size (grow or shrink).

    Dimensions left as ``None`` keep the current topology's value
    (derived via :func:`fleet_dims`), so ``rescale(topo, n_pods=4)``
    changes only the pod count. Historically ``topo`` was silently
    ignored and all three dimensions were required.
    """
    cur_pods, cur_racks, cur_chips = fleet_dims(topo)
    return fleet_tree(
        n_pods=cur_pods if n_pods is None else n_pods,
        racks_per_pod=cur_racks if racks_per_pod is None else racks_per_pod,
        chips_per_rack=cur_chips if chips_per_rack is None else chips_per_rack)


def shrink_by_failure(topo: ClusterTopology, dead: list[int]) -> ClusterTopology:
    """In-place shrink: keep the tree, drop the dead chips' load."""
    return fail_devices(topo, dead)


def scaling_budget(k: int, old_devices: int, new_devices: int,
                   policy: str = "proportional") -> int:
    """How the blue budget moves when the fleet is rescaled.

    proportional: k scales with device count (NaaS per-tenant contract);
    fixed: the tenant bought k switches, size changes don't alter it.
    """
    if policy == "fixed":
        return k
    if policy == "proportional":
        return max(1, round(k * new_devices / max(1, old_devices)))
    raise ValueError(f"unknown budget policy {policy!r}")
