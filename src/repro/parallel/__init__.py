from .sharding import (
    AxisRules,
    axis_rules,
    cs,
    current_rules,
    logical_spec,
    param_sharding_specs,
)

__all__ = [
    "AxisRules", "axis_rules", "cs", "current_rules", "logical_spec",
    "param_sharding_specs",
]
