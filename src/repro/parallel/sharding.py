"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Model code annotates activations with *logical* axis names via ``cs(x, ...)``;
a launcher installs an :class:`AxisRules` mapping logical names to mesh axes.
Without installed rules every annotation is a no-op, so the same model code
runs in single-device smoke tests and in the 512-device dry-run.

Parameter shardings are assigned by leaf-path regex (``param_sharding_specs``),
so any pytree produced by the model inits gets a complete sharding without
per-module plumbing.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


class AxisRules(dict):
    """logical axis name -> mesh axis (str | tuple | None)."""


# Default production rules: batch over (pod, data); model-parallel dims over
# `model`; FSDP weight shard over (pod, data).
def make_rules(multi_pod: bool, seq_shard: bool = False,
               fsdp: bool = True) -> AxisRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return AxisRules(
        batch=dp,
        seq="model" if seq_shard else None,   # SP: shard long sequences
        embed=None,
        heads="model",
        kv_heads="model",
        ff="model",
        vocab="model",
        experts="model",
        expert_cap=None,
        fsdp=dp if fsdp else None,
        tokens_flat=dp + ("model",),          # MoE dispatch: full flattening
        state="model",
    )


@contextlib.contextmanager
def axis_rules(rules: AxisRules | None, mesh=None):
    prev = getattr(_STATE, "rules", None)
    prev_mesh = getattr(_STATE, "mesh", None)
    _STATE.rules = rules
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules = prev
        _STATE.mesh = prev_mesh


def current_rules() -> AxisRules | None:
    return getattr(_STATE, "rules", None)


def current_mesh():
    """Mesh installed alongside the rules (for shard_map'd interiors)."""
    return getattr(_STATE, "mesh", None)


def logical_spec(*names: str | None) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return P(*[rules.get(n) if n else None for n in names])


def cs(x, *names: str | None):
    """Constrain activation sharding by logical axis names (no-op w/o rules)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_spec(*names))


# ---------------------------------------------------------------------------
# Parameter shardings by leaf path
# ---------------------------------------------------------------------------

# Order matters: first match wins. Patterns run against '/'-joined tree paths.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r".*embed_tokens$",           ("vocab", "fsdp")),
    (r".*lm_head$",                ("fsdp", "vocab")),
    (r".*pos_embed$",              (None, "fsdp")),
    # MoE expert stacks: (E, d, ff) / (E, ff, d)
    (r".*experts/w_(gate|up)$",    ("experts", "fsdp", None)),
    (r".*experts/w_down$",         ("experts", None, "fsdp")),
    (r".*router/w$",               ("fsdp", None)),
    # attention projections
    (r".*w_q$|.*w_uq$",            ("fsdp", "heads")),
    (r".*w_(k|v)$",                ("fsdp", "heads")),
    (r".*w_o$",                    ("heads", "fsdp")),
    (r".*w_dq$|.*w_dkv$",          ("fsdp", None)),
    (r".*w_ukv$",                  (None, "heads")),
    # dense MLPs: (d, ff) / (ff, d)
    (r".*w_(gate|up)$",            ("fsdp", "ff")),
    (r".*w_down$",                 ("ff", "fsdp")),
    # SSM mixers
    (r".*ssm/(w_in|w_x)$",         ("fsdp", "heads")),
    (r".*ssm/w_out$",              ("heads", "fsdp")),
    (r".*ssm/.*$",                 (None,)),
    (r".*mix/(w_in|out_gate)$",    ("fsdp", "heads")),
    # norms / scalars / everything else: replicated
    (r".*",                        ()),
]


def _spec_for_path(path: str, rules: AxisRules, stacked: bool) -> P:
    for pat, names in _PARAM_RULES:
        if re.fullmatch(pat, path):
            axes = [rules.get(n) if n else None for n in names]
            if stacked:
                axes = [None] + axes  # leading scanned-layer axis
            return P(*axes)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_sharding_specs(params: Any, rules: AxisRules,
                         stacked_prefixes: tuple = ("layers",)) -> Any:
    """PartitionSpec pytree matching ``params``.

    Leaves under a subtree named in ``stacked_prefixes`` (the lax.scan layer
    stacks) get a leading None axis for the layer dimension.
    """

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        stacked = any(f"/{sp}/" in f"/{ps}/" for sp in stacked_prefixes)
        spec = _spec_for_path(ps, rules, stacked)
        if len(spec) > getattr(leaf, "ndim", 0):
            spec = P(*list(spec)[: leaf.ndim])
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params)
