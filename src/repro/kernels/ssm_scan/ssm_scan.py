"""Pallas TPU kernel: chunked selective-SSM scan (the §Perf hymba hot path).

Computes the Mamba recurrence over one sequence chunk per grid step,
carrying the (B_blk, D_blk, N) state across the chunk axis in a VMEM
scratch ref (TPU grid steps run in order on a core, so the scratch is the
cross-chunk carry — the same dataflow as models/ssm.py::mamba_forward's
lax.scan, with the chunk body living entirely in VMEM):

    s_t = s_{t-1} * exp(delta_t * A) + (delta_t * u_t) x B_t
    y_t = <s_t, C_t>_N

Grid: (batch blocks, channel blocks, chunks) — chunks innermost so the
carry is correct; channels are independent (A is per-(d, n)), so D tiles
freely. The sequential c-step loop runs on the VPU over (B_blk, D_blk, N)
tiles; N (the state width, 16) rides the lane dimension with D_blk on the
sublane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, s0_ref,
                     y_ref, sf_ref, s_scr):
    j = pl.program_id(2)                       # chunk index (innermost)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = s0_ref[...]               # (B_blk, D_blk, N)

    u = u_ref[...]                             # (B_blk, c, D_blk)
    dt = dt_ref[...]                           # (B_blk, c, 1)
    bv = b_ref[...]                            # (B_blk, c, N)
    cv = c_ref[...]                            # (B_blk, c, N)
    a = a_ref[...]                             # (1, D_blk, N)
    s = s_scr[...]
    cc = u.shape[1]

    def step(t, carry):
        s, y = carry
        d_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 1)      # (B,1,1)
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 1)[:, 0]  # (B,D)
        b_t = jax.lax.dynamic_slice_in_dim(bv, t, 1, 1)[:, 0]  # (B,N)
        c_t = jax.lax.dynamic_slice_in_dim(cv, t, 1, 1)[:, 0]  # (B,N)
        decay = jnp.exp(d_t * a)                              # (B,D,N)
        w = (d_t[:, 0] * u_t)[..., None] * b_t[:, None, :]    # (B,D,N)
        s = s * decay + w
        y_t = jnp.sum(s * c_t[:, None, :], axis=-1)           # (B,D)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[:, None], t, 1)
        return s, y

    y0 = jnp.zeros(u.shape, u.dtype)
    s, y = jax.lax.fori_loop(0, cc, step, (s, y0))
    s_scr[...] = s
    y_ref[...] = y
    sf_ref[...] = s


def ssm_chunk_scan_pallas(u, delta, bv, cv, a, s0, chunk: int = 256,
                          block_b: int = 8, block_d: int = 256,
                          interpret: bool = False):
    """u: (B,T,D) f32; delta: (B,T,1); bv/cv: (B,T,N); a: (D,N); s0: (B,D,N).

    Returns (y: (B,T,D), s_final: (B,D,N)).
    """
    B, T, D = u.shape
    N = bv.shape[-1]
    assert T % chunk == 0
    nch = T // chunk
    block_b = min(block_b, B)
    block_d = min(block_d, D)
    grid = (pl.cdiv(B, block_b), pl.cdiv(D, block_d), nch)
    y, s_f = pl.pallas_call(
        _ssm_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, chunk, block_d),
                         lambda i, l, j: (i, j, l)),       # u
            pl.BlockSpec((block_b, chunk, 1), lambda i, l, j: (i, j, 0)),
            pl.BlockSpec((block_b, chunk, N), lambda i, l, j: (i, j, 0)),
            pl.BlockSpec((block_b, chunk, N), lambda i, l, j: (i, j, 0)),
            pl.BlockSpec((1, block_d, N), lambda i, l, j: (0, l, 0)),  # a
            pl.BlockSpec((block_b, block_d, N), lambda i, l, j: (i, l, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, chunk, block_d),
                         lambda i, l, j: (i, j, l)),       # y
            pl.BlockSpec((block_b, block_d, N), lambda i, l, j: (i, l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), u.dtype),
            jax.ShapeDtypeStruct((B, D, N), s0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, block_d, N), jnp.float32)],
        interpret=interpret,
    )(u, delta, bv, cv, a[None], s0)
    return y, s_f
