from .ops import ssm_chunk_scan

__all__ = ["ssm_chunk_scan"]
