"""Jitted wrapper for the chunked selective-SSM scan kernel."""
from __future__ import annotations

import functools

import jax

from .ref import ssm_chunk_scan_ref
from .ssm_scan import ssm_chunk_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_pallas"))
def ssm_chunk_scan(u, delta, bv, cv, a, s0, chunk: int = 256,
                   interpret: bool = True, use_pallas: bool = True):
    """Selective-SSM scan: returns (y, final_state). Shapes per ref.py."""
    B, T, D = u.shape
    N = bv.shape[-1]
    if delta.shape != (B, T, 1) or cv.shape != (B, T, N):
        raise ValueError(f"bad shapes delta={delta.shape} cv={cv.shape}")
    if a.shape != (D, N) or s0.shape != (B, D, N):
        raise ValueError(f"bad shapes a={a.shape} s0={s0.shape}")
    if not use_pallas or T % chunk not in (0,):
        return ssm_chunk_scan_ref(u, delta, bv, cv, a, s0)
    return ssm_chunk_scan_pallas(u, delta, bv, cv, a, s0, chunk=chunk,
                                 interpret=interpret)
