"""Pure-jnp oracle for the chunked selective-SSM scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_chunk_scan_ref(u, delta, bv, cv, a, s0):
    """Sequential reference: u (B,T,D), delta (B,T,1), bv/cv (B,T,N),
    a (D,N), s0 (B,D,N) -> (y (B,T,D), s_final (B,D,N))."""

    def step(s, inp):
        u_t, d_t, b_t, c_t = inp                 # (B,D),(B,1),(B,N),(B,N)
        decay = jnp.exp(d_t[..., None] * a[None])
        s = s * decay + (d_t * u_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", s, c_t)
        return s, y_t

    xs = (u.swapaxes(0, 1), delta.swapaxes(0, 1), bv.swapaxes(0, 1),
          cv.swapaxes(0, 1))
    s_f, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), s_f
