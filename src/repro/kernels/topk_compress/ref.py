"""Pure-jnp oracle: top-k by |x| per row, first-index tie-break."""
import jax
import jax.numpy as jnp


def topk_compress_ref(x, k: int):
    """x: (R, D) -> (values (R, k), indices (R, k)), ordered by |x| desc."""
    mag = jnp.abs(x.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)            # lower index wins ties
    idx = idx.astype(jnp.int32)
    vals = jnp.take_along_axis(x, idx, axis=1)
    return vals, idx
