"""Pallas TPU kernel: per-row top-k magnitude selection (gradient compression).

The distributed-optimization path compresses gradient shards before they
enter the SOAR-scheduled reduction tree: each row (a flattened gradient
block) keeps its k largest-|x| entries. The kernel runs k argmax rounds over
a VMEM-resident row tile — O(kD) VPU work, no sort, deterministic ties
(first index wins), which keeps compression reproducible across replicas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, v_ref, i_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)        # (TB, D)
    tb, d = x.shape

    def body(j, carry):
        cur = carry
        mag = jnp.abs(cur)
        idx = jnp.argmax(mag, axis=1)                       # (TB,)
        val = jnp.take_along_axis(cur, idx[:, None], axis=1)  # (TB, 1)
        pl.store(v_ref, (slice(None), pl.dslice(j, 1)), val.astype(v_ref.dtype))
        pl.store(i_ref, (slice(None), pl.dslice(j, 1)), idx[:, None].astype(jnp.int32))
        cur = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (tb, d), 1) == idx[:, None],
            0.0, cur)
        return cur

    jax.lax.fori_loop(0, k, body, x)


def topk_compress_pallas(x: jax.Array, k: int, block_rows: int = 8,
                         interpret: bool = False):
    """x: (R, D) -> (values (R, k), indices (R, k))."""
    r, d = x.shape
    grid = (pl.cdiv(r, block_rows),)
    kernel = functools.partial(_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k), x.dtype),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
