"""Jitted wrapper for top-k gradient compression."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import topk_compress_ref
from .topk_compress import topk_compress_pallas


@functools.partial(jax.jit, static_argnames=("k", "interpret", "use_pallas"))
def topk_compress(x: jax.Array, k: int, interpret: bool = True,
                  use_pallas: bool = True):
    """(R, D) -> (values (R, k), indices (R, k)) by descending magnitude."""
    if x.ndim != 2 or not 0 < k <= x.shape[1]:
        raise ValueError(f"bad input {x.shape}, k={k}")
    if not use_pallas:
        return topk_compress_ref(x, k)
    return topk_compress_pallas(x, k, interpret=interpret)


def decompress(values: jax.Array, indices: jax.Array, d: int) -> jax.Array:
    """Scatter the kept entries back to dense (R, d)."""
    r, k = values.shape
    out = jnp.zeros((r, d), values.dtype)
    return out.at[jnp.arange(r)[:, None], indices].set(values)
