"""Jitted wrapper for the aggregation kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import segment_reduce_ref
from .segment_reduce import segment_reduce_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def segment_reduce(x: jax.Array, mask: jax.Array, interpret: bool = True,
                   use_pallas: bool = True) -> jax.Array:
    """Masked sum over the child axis: (G, C, D), (G, C) -> (G, D)."""
    if x.ndim != 3 or mask.shape != x.shape[:2]:
        raise ValueError(f"bad shapes {x.shape} {mask.shape}")
    if not use_pallas:
        return segment_reduce_ref(x, mask)
    return segment_reduce_pallas(x, mask, interpret=interpret)
