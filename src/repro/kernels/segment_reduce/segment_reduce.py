"""Pallas TPU kernel: masked group-sum — the blue-switch aggregation.

The Reduce primitive of the paper (Algorithm 1): an aggregating switch
collapses up to C incoming child messages (gradient shards of width D) into
one. Batched over G independent groups (one per aggregation point):

    out[g, d] = sum_c mask[g, c] * x[g, c, d]

Tiled (1 group, all C children, TD lanes) per grid step so the child stack
streams through VMEM; the sum runs on the VPU at full lane width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(x_ref, m_ref, o_ref):
    x = x_ref[...]                   # (1, C, TD)
    m = m_ref[...]                   # (1, C, 1)
    o_ref[...] = jnp.sum(x * m, axis=1)  # (1, TD)


def segment_reduce_pallas(x: jax.Array, mask: jax.Array,
                          block_d: int = 512,
                          interpret: bool = False) -> jax.Array:
    """x: (G, C, D) float; mask: (G, C) -> (G, D)."""
    g, c, d = x.shape
    m = mask.astype(x.dtype)[:, :, None]
    grid = (g, pl.cdiv(d, block_d))
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, c, 1), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g, d), x.dtype),
        interpret=interpret,
    )(x, m)
