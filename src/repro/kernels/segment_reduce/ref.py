"""Pure-jnp oracle for the masked group-sum."""
import jax.numpy as jnp


def segment_reduce_ref(x, mask):
    """x: (G, C, D); mask: (G, C) -> (G, D)."""
    return jnp.einsum("gcd,gc->gd", x, mask.astype(x.dtype))
