"""Fused level-fold: one launch per tree level of the batched SOAR-Gather.

The level-synchronous gather in ``repro.engine`` folds, for every internal
node of a depth level, the min-plus convolutions of all its children's DP
tables (the mCost chain of Algorithm 3), then applies the red/blue
recurrence. PR 1 dispatched that as one ``pallas_call`` *per child index*
(``O(max_children)`` launches per level) with the gathered child rows and
every partial accumulator round-tripping through HBM. This module fuses
the whole fold into a single kernel per level:

  * the kernel receives the *child level's* table block (children always
    live exactly one level down; one batch element per grid step), gathers
    each child's rows out of it in-kernel, and chains the min-plus
    convolutions **in-register** — the ``(rows, K)`` partial accumulators
    never leave VMEM;
  * the red chain (child rows ``1..nl``), the blue chain (child row 1),
    the availability mask, the blue budget shift and the at-most-k
    ``cummin`` all happen in the same kernel body, so a level costs one
    launch and one HBM write (the level's output block).

``level_fold`` is the dispatcher: ``use_pallas=True`` runs the Pallas
kernel (``interpret=True`` executes its body in Python — the CPU-container
validation mode; budget widths are lane-padded to 128 inside
``level_fold_pallas``; TPU tiling note: the in-kernel child gathers land
on the sublane axis, which is the part to revisit if a real-TPU lowering
rejects the kernel), ``use_pallas=False`` runs ``level_fold_jnp``, a fused
jnp formulation of the identical math that XLA fuses into one loop nest on
CPU/GPU.

All arithmetic runs on the finite ``BIG`` sentinel from
``repro.core.tropical`` (never ``inf``: padded slots multiply by zero
loads, and ``0 * inf`` is NaN), and both paths share
:func:`minplus_fused`, so they agree bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.tropical import BIG


def minplus_fused(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused min-plus convolution, (rows, K) x (rows, K) -> (rows, K).

    The j-shift reduction unrolled over the (static) budget width so XLA
    keeps everything in one elementwise loop — no (rows, K, K) candidate
    tensor is ever materialized. Identical candidate order on every
    backend, hence bit-identical results.
    """
    rows, k = a.shape
    acc = a + b[:, :1]
    for j in range(1, k):
        shifted = jnp.concatenate(
            [jnp.full((rows, j), BIG, a.dtype), a[:, : k - j]], axis=1)
        acc = jnp.minimum(acc, shifted + b[:, j : j + 1])
    return acc


def chain_fold(st: jax.Array, collect: bool = False):
    """Fold a stack of row-batches through the min-plus chain.

    ``st``: (max_c, R, K) — child 0 first. Returns the final accumulator
    (R, K), plus (when ``collect=True``) the full (max_c, R, K) prefix
    stack (partial chains, needed by the color traceback's mSplit
    replay). One lax.scan over the child index: identical fold order to
    an unrolled loop — hence bit-identical results everywhere this chain
    is spelled — at O(max_c) smaller HLO. This is the single definition
    the gather fold and the on-device color both call; keep it that way,
    the bit-identical-mask guarantee rides on it.
    """
    def fold(acc, ch):
        y = minplus_fused(acc, ch)
        return y, y

    last, partials = jax.lax.scan(fold, st[0], st[1:])
    if not collect:
        return last
    return last, jnp.concatenate([st[:1], partials], axis=0)


def rho_up_from_edges(rho_edge: jax.Array, anc: jax.Array,
                      valid: jax.Array) -> jax.Array:
    """Recompute the packed rho-up table from per-edge rates, on device.

    The congestion driver re-solves one prebuilt Forest every round under
    penalty-reweighted *edge* rates; repacking the ``(B, S, h+2)``
    cumulative table on the host (as ``Tree.rho_up_table`` does) would
    drag the loop off the accelerator. This recomputes it from the slot
    layout instead:

        rho_up[b, s, ell] = sum_{j < ell} rho_edge[b, anc[b, s, j]]

    ``rho_edge``: (B, S) effective up-edge rate per slot (finite
    everywhere — padded slots carry 0); ``anc``: (B, S, h_max+1) int32,
    ``anc[b, s, j]`` = slot of the j-th ancestor of s (j=0 is s itself;
    entries past the root point at slot 0 and are masked); ``valid``:
    (B, S, h_max+2) bool — True exactly where the host table is finite.
    Returns (B, S, h_max+2) with ``BIG`` at invalid entries.

    The accumulation order is one edge per hop, left to right — the
    *same* per-node association as the host ``Tree.rho_up_table`` walk —
    so on rates that are exactly representable (the dyadic-quantized
    penalty weights on dyadic-rho trees) the result is bit-identical to
    packing the host table and casting. Masked lanes accumulate finite
    garbage (real edge rates, never BIG) that the mask discards.
    """
    B, S = rho_edge.shape
    dt = rho_edge.dtype
    H2 = valid.shape[2]
    acc = jnp.zeros((B, S), dt)
    rows = [jnp.where(valid[:, :, 0], acc, BIG)]
    for ell in range(1, H2):
        acc = acc + jnp.take_along_axis(rho_edge, anc[:, :, ell - 1], axis=1)
        rows.append(jnp.where(valid[:, :, ell], acc, BIG))
    return jnp.stack(rows, axis=2)


def scaled_edges(rho_edge: jax.Array, scale: jax.Array,
                 extra: jax.Array | None = None,
                 root_idx: jax.Array | None = None) -> jax.Array:
    """Effective per-edge rates: ``rho_edge * scale``, optionally with an
    additive extension on each instance's root edge.

    The additive term is how the fleet congestion driver folds shared-core
    transit into the per-tree DP: a tenant's root-crossing messages also
    traverse its core path, so the core links' (penalty-weighted) rates
    extend the root up-edge — additively, because core hops are in series
    with the root hop. ``extra``: (B,) per-instance extension; ``root_idx``:
    (B,) int column of each instance's root edge. Both loop flavors of the
    driver call this single definition (multiplied then extended in the
    same order), which is what keeps their effective edge rates
    bit-identical; :func:`rho_up_from_edges` then accumulates them into
    the packed rho-up table on device.
    """
    edges = rho_edge * scale
    if extra is None:
        return edges
    B = edges.shape[0]
    return edges.at[jnp.arange(B), root_idx].add(extra)


def _minplus_loop(a: jax.Array, b: jax.Array) -> jax.Array:
    """minplus_fused spelled as a fori_loop (for kernel bodies).

    Identical candidate order and BIG shift padding — bit-identical
    results — but O(1) HLO in the budget width, so lane-padded kernels
    don't pay a 128-step unroll at trace time.
    """
    rows, kk = a.shape
    a_pad = jnp.concatenate([jnp.full((rows, kk), BIG, a.dtype), a], axis=1)

    def body(j, acc):
        seg = jax.lax.dynamic_slice(a_pad, (0, kk - j), (rows, kk))
        bj = jax.lax.dynamic_slice(b, (0, j), (rows, 1))
        return jnp.minimum(acc, seg + bj)

    return jax.lax.fori_loop(1, kk, body, a + b[:, :1])


def _fold_math(xs, xb, kid, load, send, avail, rho, nl, kcap):
    """Shared recurrence body: chain children, apply red/blue, cummin.

    xs:   (C, nl, kcap) child-level tables at rows 1..nl, all-zeros
          identity appended at index C-1
    xb:   (C, kcap)     the same at row 1 (the blue chain operand)
    kid:  (W, max_c) int32 child-level-local indices (sentinel = C-1)
    load, send: (W,) float; avail: (W,) bool; rho: (W, nl) float
    returns (W, nl, kcap)
    """
    w, max_c = kid.shape
    dt = xs.dtype
    acc_r = jnp.take(xs, kid[:, 0], axis=0)            # (W, nl, kcap)
    acc_b = jnp.take(xb, kid[:, 0], axis=0)            # (W, kcap)
    for m in range(1, max_c):
        ch_r = jnp.take(xs, kid[:, m], axis=0)
        ch_b = jnp.take(xb, kid[:, m], axis=0)
        # one fused convolution over all (v, ell) rows + the blue rows
        a = jnp.concatenate([acc_r.reshape(-1, kcap), acc_b])
        b = jnp.concatenate([ch_r.reshape(-1, kcap), ch_b])
        y = _minplus_loop(a, b)
        acc_r = y[: w * nl].reshape(w, nl, kcap)
        acc_b = y[w * nl :]
    rl = rho[:, :, None]                               # (W, nl, 1)
    red = acc_r + load[:, None, None] * rl
    # blue: budget shifts by one (v spends a slot on itself)
    blue = jnp.concatenate(
        [jnp.full((w, nl, 1), BIG, dt),
         acc_b[:, None, :-1] + send[:, None, None] * rl], axis=-1)
    blue = jnp.where(avail[:, None, None], blue, BIG)
    out = jnp.minimum(red, blue)
    return jax.lax.cummin(out, axis=2)                 # at-most-k monotone


def level_fold_jnp(xs, xb, kid, load, send, avail, rho, *, nl: int,
                  kcap: int):
    """Fused-jnp level fold — batched :func:`_fold_math` math, spelled with
    ``take_along_axis`` over the leading batch axis (cheaper for XLA:CPU to
    compile than a vmapped per-instance body).

    xs: (B, C, nl, kcap) the child level's tables at rows 1..nl, identity
    (all-zeros) appended at index C-1; xb: (B, C, kcap) the same at row 1
    (the blue-chain operand); kid: (B, W, max_c) *child-level-local*
    indices (sentinel C-1); load, send: (B, W); avail: (B, W) bool; rho:
    (B, W, nl). Returns the level's internal block values,
    (B, W, nl, kcap).
    """
    B, W, max_c = kid.shape
    dt = xs.dtype
    # gather every child's red rows + blue row in one go: (B, W, max_c, ...)
    g_r = jnp.take_along_axis(xs, kid.reshape(B, -1)[:, :, None, None],
                              axis=1).reshape(B, W, max_c, nl, kcap)
    g_b = jnp.take_along_axis(xb, kid.reshape(B, -1)[:, :, None],
                              axis=1).reshape(B, W, max_c, kcap)
    rows_r = jnp.moveaxis(g_r, 2, 0).reshape(max_c, B * W * nl, kcap)
    rows_b = jnp.moveaxis(g_b, 2, 0).reshape(max_c, B * W, kcap)
    chs = jnp.concatenate([rows_r, rows_b], axis=1)    # (max_c, R, kcap)
    acc = chain_fold(chs)
    acc_r = acc[: B * W * nl].reshape(B, W, nl, kcap)
    acc_b = acc[B * W * nl :].reshape(B, W, kcap)
    rl = rho[..., None]                                # (B, W, nl, 1)
    red = acc_r + load[:, :, None, None] * rl
    blue = jnp.concatenate(
        [jnp.full((B, W, nl, 1), BIG, dt),
         acc_b[:, :, None, :-1] + send[:, :, None, None] * rl], axis=-1)
    blue = jnp.where(avail[:, :, None, None], blue, BIG)
    out = jnp.minimum(red, blue)
    return jax.lax.cummin(out, axis=3)                 # at-most-k monotone


def _levelfold_kernel(xs_ref, xb_ref, kid_ref, load_ref, send_ref,
                      avail_ref, rho_ref, o_ref, *, nl: int, kcap: int):
    out = _fold_math(
        xs_ref[0], xb_ref[0], kid_ref[0], load_ref[0],
        send_ref[0], avail_ref[0] > 0, rho_ref[0], nl, kcap)
    o_ref[0] = out


LANE = 128


def level_fold_pallas(xs, xb, kid, load, send, avail, rho, *, nl: int,
                      kcap: int, interpret: bool = False):
    """One-launch-per-level Pallas fold; same contract as level_fold_jnp.

    Grid is the batch: each step holds one instance's child-level table
    block in VMEM, gathers child rows from it and chains the convolutions
    without writing partials back to HBM. The budget axis is padded to
    the 128-lane boundary with BIG (same discipline as ops.minplus —
    min-plus output column i only reads operand columns <= i, so BIG
    lanes never leak into the real prefix) and sliced back after.
    """
    B, C, _, _ = xs.shape
    _, W, max_c = kid.shape
    dt = xs.dtype
    kp = ((kcap + LANE - 1) // LANE) * LANE
    xs = jnp.pad(xs, ((0, 0), (0, 0), (0, 0), (0, kp - kcap)),
                 constant_values=BIG)
    xb = jnp.pad(xb, ((0, 0), (0, 0), (0, kp - kcap)), constant_values=BIG)

    def bspec(shape):
        return pl.BlockSpec((1, *shape), lambda b: (b,) + (0,) * len(shape))

    out = pl.pallas_call(
        functools.partial(_levelfold_kernel, nl=nl, kcap=kp),
        grid=(B,),
        in_specs=[bspec((C, nl, kp)), bspec((C, kp)), bspec((W, max_c)),
                  bspec((W,)), bspec((W,)), bspec((W,)), bspec((W, nl))],
        out_specs=bspec((W, nl, kp)),
        out_shape=jax.ShapeDtypeStruct((B, W, nl, kp), dt),
        interpret=interpret,
    )(xs, xb, kid, load, send, avail.astype(jnp.int32), rho)
    return out[..., :kcap]


def level_fold(xs, xb, kid, load, send, avail, rho, *, nl: int, kcap: int,
               use_pallas: bool = False, interpret: bool = False):
    """Backend dispatch for the fused level fold (see module docstring)."""
    if use_pallas:
        return level_fold_pallas(xs, xb, kid, load, send, avail, rho,
                                 nl=nl, kcap=kcap, interpret=interpret)
    return level_fold_jnp(xs, xb, kid, load, send, avail, rho,
                          nl=nl, kcap=kcap)
