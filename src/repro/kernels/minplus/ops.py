"""Jitted wrapper: shape checks, lane padding, dtype handling."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.tropical import BIG
from .minplus import minplus_pallas
from .ref import minplus_ref

LANE = 128


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def minplus(a: jax.Array, b: jax.Array, interpret: bool = True,
            use_pallas: bool = True) -> jax.Array:
    """Batched tropical convolution with TPU lane padding.

    a, b: (rows, K) -> (rows, K). interpret=True executes the Pallas kernel
    body in Python (the CPU-container validation mode); on real TPUs pass
    interpret=False.
    """
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    if not use_pallas:
        return minplus_ref(a, b)
    rows, k = a.shape
    kp = ((k + LANE - 1) // LANE) * LANE
    dt = a.dtype
    af = jnp.pad(a.astype(jnp.float32), ((0, 0), (0, kp - k)),
                 constant_values=BIG)
    bf = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, kp - k)),
                 constant_values=BIG)
    out = minplus_pallas(af, bf, interpret=interpret)
    return out[:, :k].astype(dt)
