"""Pure-jnp oracle for the batched min-plus convolution.

Infeasible split positions carry the finite ``BIG`` sentinel (shared with
the kernel and the engine's fused path) rather than ``inf``, so all three
implementations saturate identically.
"""
import jax.numpy as jnp

from ...core.tropical import BIG


def minplus_ref(a, b):
    """a, b: (rows, K) -> (rows, K); C[r,i] = min_{j<=i} a[r,i-j]+b[r,j]."""
    rows, k = a.shape
    i = jnp.arange(k)[:, None]          # output index
    j = jnp.arange(k)[None, :]          # split index
    gather = jnp.where(i - j >= 0, i - j, 0)
    a_shift = a[:, gather]                          # (rows, K, K): a[i-j]
    cand = a_shift + b[:, None, :]
    cand = jnp.where((i - j >= 0)[None], cand, BIG)
    return cand.min(axis=-1)
