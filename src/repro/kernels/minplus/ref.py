"""Pure-jnp oracle for the batched min-plus convolution."""
import jax.numpy as jnp


def minplus_ref(a, b):
    """a, b: (rows, K) -> (rows, K); C[r,i] = min_{j<=i} a[r,i-j]+b[r,j]."""
    rows, k = a.shape
    i = jnp.arange(k)[:, None]          # output index
    j = jnp.arange(k)[None, :]          # split index
    gather = jnp.where(i - j >= 0, i - j, 0)
    a_shift = a[:, gather]                          # (rows, K, K): a[i-j]
    cand = a_shift + b[:, None, :]
    cand = jnp.where((i - j >= 0)[None], cand, jnp.inf)
    return cand.min(axis=-1)
