"""Pallas TPU kernel: batched min-plus (tropical) convolution.

SOAR-Gather's mCost inner loop (paper Alg. 3 lines 30-34) is, for every
(node, ell) pair, the min-plus convolution of two monotone budget vectors:

    C[b, i] = min_{0 <= j <= i}  A[b, i-j] + B[b, j]

The level-synchronous gather batches all (node, ell) rows of a tree level;
this kernel tiles the batch into VMEM blocks and runs the j-shift reduction
on the VPU. Budget width K is padded to the 128-lane boundary by ops.py.

Infeasible shift positions and lane padding use the finite ``BIG``
sentinel from ``repro.core.tropical`` — the same stand-in the engine's
fused jnp path runs on — so ``0 * pad`` can never go NaN and the
interpret-mode kernel matches the fused path bit-for-bit.
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from .levelfold import _minplus_loop


def _minplus_kernel(a_ref, b_ref, o_ref):
    # one shared definition of the BIG-padded j-shift reduction (also the
    # level-fold kernel's inner loop) — candidate order is what keeps the
    # kernels bit-identical to the fused jnp path
    o_ref[...] = _minplus_loop(a_ref[...], b_ref[...])


def minplus_pallas(a: jax.Array, b: jax.Array, block_rows: int = 128,
                   interpret: bool = False) -> jax.Array:
    """a, b: (rows, K) float32, K a multiple of 128 (pad in ops.py)."""
    rows, k = a.shape
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, k), a.dtype),
        interpret=interpret,
    )(a, b)
