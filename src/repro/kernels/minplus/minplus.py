"""Pallas TPU kernel: batched min-plus (tropical) convolution.

SOAR-Gather's mCost inner loop (paper Alg. 3 lines 30-34) is, for every
(node, ell) pair, the min-plus convolution of two monotone budget vectors:

    C[b, i] = min_{0 <= j <= i}  A[b, i-j] + B[b, j]

The level-synchronous gather batches all (node, ell) rows of a tree level;
this kernel tiles the batch into VMEM blocks and runs the j-shift reduction
on the VPU. Budget width K is padded to the 128-lane boundary by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _minplus_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]                       # (TB, K)
    b = b_ref[...]                       # (TB, K)
    tb, k = a.shape
    inf = float("inf")
    pad = jnp.full((tb, k), inf, a.dtype)
    a_pad = jnp.concatenate([pad, a], axis=1)      # (TB, 2K)

    def body(j, acc):
        seg = jax.lax.dynamic_slice(a_pad, (0, k - j), (tb, k))
        bj = jax.lax.dynamic_slice(b, (0, j), (tb, 1))
        return jnp.minimum(acc, seg + bj)

    o_ref[...] = jax.lax.fori_loop(0, k, body,
                                   jnp.full((tb, k), inf, a.dtype))


def minplus_pallas(a: jax.Array, b: jax.Array, block_rows: int = 128,
                   interpret: bool = False) -> jax.Array:
    """a, b: (rows, K) float32, K a multiple of 128 (pad in ops.py)."""
    rows, k = a.shape
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, k), a.dtype),
        interpret=interpret,
    )(a, b)
