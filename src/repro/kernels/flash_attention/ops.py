"""Jitted wrapper: head folding, padding, ref fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "interpret", "use_pallas"))
def flash_attention(q, k, v, causal: bool = True, interpret: bool = True,
                    use_pallas: bool = True):
    """(BH, T, D) attention; set use_pallas=False for the jnp oracle path."""
    if q.ndim != 3 or k.shape != v.shape:
        raise ValueError(f"bad shapes {q.shape} {k.shape} {v.shape}")
    if not use_pallas:
        return flash_attention_ref(q, k, v, causal)
    bh, t, d = q.shape
    s = k.shape[1]
    bq = min(128, t)
    bk = min(128, s)
    # pad T/S to block multiples (extra keys masked out by causal/-inf logic
    # only when causal; for bidirectional we mask via ref fallback)
    tp = ((t + bq - 1) // bq) * bq
    sp = ((s + bk - 1) // bk) * bk
    if (tp != t or sp != s) and not causal:
        return flash_attention_ref(q, k, v, causal)
    qp = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0)))
    if sp != s:  # keep padded keys out of the softmax
        kp = kp.at[:, s:, :].set(0.0)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, block_q=bq,
                                 block_k=bk, interpret=interpret)
    return out[:, :t, :]
