"""Pure-jnp oracle: masked softmax attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (BH, T, D); k, v: (BH, S, D) -> (BH, T, D)."""
    bh, t, d = q.shape
    s = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("btd,bsd->bts", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(t)[:, None]
        logits = jnp.where(mask[None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bts,bsd->btd", w.astype(v.dtype), v)
