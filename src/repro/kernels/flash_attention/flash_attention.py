"""Pallas TPU kernel: blocked online-softmax (flash) attention.

This is the model-zoo compute hot-spot for the prefill cells: the pure-jnp
path materializes the (T, T) logits in HBM; this kernel keeps per-block
running max / normalizer in VMEM so only q/k/v/o ever touch HBM.

Layout: q (BH, T, D), k/v (BH, S, D) with heads folded into the batch dim
(GQA grouping is the caller's reshape). Grid is (BH, T/block_q); each step
loops over S/block_k key tiles with the standard m/l rescaling recurrence.
K/V tiles are sliced from VMEM-resident per-BH panels (adequate up to ~8k
context; longer contexts stream via the ops.py chunking wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float,
                  causal: bool):
    q = q_ref[...][0]                    # (block_q, D)
    k_all = k_ref[...][0]                # (S, D)
    v_all = v_ref[...][0]                # (S, D)
    bq, d = q.shape
    s = k_all.shape[0]
    q_idx = pl.program_id(1)

    nblocks = pl.cdiv(s, block_k)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    def body(kb, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_all, (kb * block_k, 0), (block_k, d))
        v = jax.lax.dynamic_slice(v_all, (kb * block_k, 0), (block_k, d))
        logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
        if causal:
            qpos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            logits = jnp.where(kpos <= qpos, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[None].astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (BH, T, D); k, v: (BH, S, D) -> (BH, T, D)."""
    bh, t, d = q.shape
    s = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    grid = (bh, pl.cdiv(t, block_q))
    kernel = functools.partial(_flash_kernel, block_k=block_k, scale=scale,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
