"""Deterministic sharded synthetic data pipeline.

Every (step, host) pair derives its sample from a counter-based seed, so:
  * restart/resume is exact (no pipeline state to checkpoint beyond `step`);
  * each host materializes only its shard (1000-node posture: no host ever
    holds the global batch);
  * elastic re-scaling keeps sample identity (seeds are per global example
    index, not per host).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    zipf_s: float = 1.07            # natural-text-like marginal


class SyntheticLM:
    """Zipf-distributed token stream with a deterministic per-example seed."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-dcfg.zipf_s)
        self._pmf = p / p.sum()

    def _example(self, global_idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.dcfg.seed, global_idx))
        return rng.choice(self.cfg.vocab, size=self.dcfg.seq_len + 1,
                          p=self._pmf).astype(np.int32)

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        """Host-local shard of the global batch for `step`."""
        b = self.dcfg.global_batch
        per_host = b // n_hosts
        base = step * b + host_id * per_host
        toks = np.stack([self._example(base + i) for i in range(per_host)])
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}


def wordcount_corpus(n_words: int, vocab: int, zipf_s: float = 1.07,
                     seed: int = 0) -> np.ndarray:
    """Synthetic Zipf corpus standing in for the paper's wikipedia dump."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-zipf_s)
    return rng.choice(vocab, size=n_words, p=p / p.sum()).astype(np.int32)
