from .pipeline import DataConfig, SyntheticLM, wordcount_corpus

__all__ = ["DataConfig", "SyntheticLM", "wordcount_corpus"]
