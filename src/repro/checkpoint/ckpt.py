"""Sharded checkpointing with atomic commits and elastic restore.

Layout per step:  <dir>/step_<n>/
    manifest.json        tree structure + shapes/dtypes + step metadata
    arrays.npz           flattened leaves keyed by tree path

Writes go to a temp directory and are renamed into place (atomic on POSIX),
so a crash mid-save never corrupts the latest checkpoint — the restart path
simply loads the newest complete manifest. Restore is *elastic*: arrays are
saved unsharded and re-device_put under the (possibly different) target
sharding, so a job can resume on a different mesh size.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[key + "::bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def save(directory: str | os.PathLike, step: int, tree: Any,
         extra: dict | None = None) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = pathlib.Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_"))
    try:
        flat = _flatten(tree)
        np.savez(tmp / "arrays.npz", **flat)
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "treedef": str(treedef),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.glob("step_*"):
        if (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str | os.PathLike, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Load into the structure of `like`; re-shard onto `shardings` if given."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = directory / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: x
                    is None or hasattr(x, "spec")) if shardings is not None
                    else [None] * len(leaves_like))
    for (pathk, leaf), shard in zip(leaves_like, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        if key + "::bf16" in data:
            arr = data[key + "::bf16"].view(jnp.bfloat16)
        else:
            arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {leaf.shape}")
        out_leaves.append(jax.device_put(arr, shard) if shard is not None
                          else jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out_leaves)
    return tree, step


class CheckpointManager:
    """keep_n retention + optional async (background-thread) saves."""

    def __init__(self, directory: str | os.PathLike, keep_n: int = 3,
                 async_save: bool = True):
        self.directory = pathlib.Path(directory)
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def work():
            save(self.directory, step, host_tree, extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.glob("step_*")
            if (p / "manifest.json").exists())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
