"""Typed engine options — the planner API's single options surface.

PR 1–3 threaded a ``**engine_kw`` kwargs-soup through three layers
(``plan_batch`` → ``solve_batch`` → ``solve_forest``): a misspelled option
surfaced as a ``TypeError`` deep inside the engine (or, worse, was
silently swallowed by an intermediate ``**kw``). :class:`EngineOptions`
replaces that with one frozen dataclass validated at the call boundary:

    solve_batch(trees, loads, k, options=EngineOptions(cap=False))
    plan_batch(topos, k, options=EngineOptions(dtype=jnp.float64))

Unknown or misspelled fields fail immediately in the ``EngineOptions``
constructor (with a did-you-mean hint via :func:`resolve_options`), and a
frozen instance hashes/compares by value, so it can key jit caches
directly. The old kwargs spelling still works for one release through
:func:`resolve_options` — it raises a :class:`DeprecationWarning` naming
the migration, and CI runs a ``-W error::DeprecationWarning`` job so
internal callers cannot quietly keep using it.
"""
from __future__ import annotations

import dataclasses
import difflib
import warnings
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Options consumed by ``solve_forest`` / ``solve_batch`` and everything
    layered on top (``solve_congestion``, ``plan`` / ``plan_batch``).

    dtype:        DP table dtype (float32 default; pass ``jnp.float64``
                  under ``jax_enable_x64`` for exactness on arbitrary rates)
    use_pallas:   None = auto (Pallas level-fold kernel on TPU, fused jnp
                  elsewhere); True/False forces a backend
    interpret:    run the Pallas kernel body in Python (CPU validation)
    cap:          min(k, subtree) per-level budget-width truncation
    color:        False = costs-only mode (no traceback, no masks)
    debug_tables: full-table pullback + host-numpy color (PR 1 path)
    """

    dtype: Any = jnp.float32
    use_pallas: bool | None = None
    interpret: bool = False
    cap: bool = True
    color: bool = True
    debug_tables: bool = False

    def replace(self, **changes) -> "EngineOptions":
        """A copy with ``changes`` applied (validated like the ctor)."""
        return dataclasses.replace(self, **changes)


_FIELDS = tuple(f.name for f in dataclasses.fields(EngineOptions))

_DEPRECATION = (
    "passing engine options as keyword arguments ({names}) is deprecated; "
    "pass options=EngineOptions({example}) instead — the kwargs spelling "
    "will be removed next release"
)


def resolve_options(options: EngineOptions | None,
                    engine_kw: dict,
                    where: str,
                    stacklevel: int = 3) -> EngineOptions:
    """Merge the new ``options=`` spelling with the deprecated kwargs shim.

    * ``options`` alone → returned as-is (defaults when None);
    * legacy kwargs alone → validated against the :class:`EngineOptions`
      fields (unknown names raise ``TypeError`` *here*, at the call
      boundary, with a did-you-mean hint) and converted, with a
      ``DeprecationWarning`` pointing at the caller;
    * both at once → ``TypeError`` (ambiguous precedence is never guessed).
    """
    if not engine_kw:
        if options is None:
            return EngineOptions()
        if not isinstance(options, EngineOptions):
            raise TypeError(f"{where}: options must be an EngineOptions, "
                            f"got {type(options).__name__}")
        return options
    if options is not None:
        raise TypeError(
            f"{where}: got both options= and legacy engine keyword "
            f"arguments {sorted(engine_kw)} — pass everything through "
            "options=EngineOptions(...)")
    unknown = [k for k in engine_kw if k not in _FIELDS]
    if unknown:
        hints = []
        for k in unknown:
            close = difflib.get_close_matches(k, _FIELDS, n=1)
            hints.append(f"{k!r}" + (f" (did you mean {close[0]!r}?)"
                                     if close else ""))
        raise TypeError(
            f"{where}: unknown engine option(s) {', '.join(hints)}; "
            f"valid options: {', '.join(_FIELDS)}")
    warnings.warn(
        _DEPRECATION.format(
            names=", ".join(sorted(engine_kw)),
            example=", ".join(f"{k}=..." for k in sorted(engine_kw))),
        DeprecationWarning, stacklevel=stacklevel)
    return EngineOptions(**engine_kw)
