"""Typed engine options — the planner API's single options surface.

PR 1–3 threaded a ``**engine_kw`` kwargs-soup through three layers
(``plan_batch`` → ``solve_batch`` → ``solve_forest``): a misspelled option
surfaced as a ``TypeError`` deep inside the engine (or, worse, was
silently swallowed by an intermediate ``**kw``). :class:`EngineOptions`
replaces that with one frozen dataclass validated at the call boundary:

    solve_batch(trees, loads, k, options=EngineOptions(cap=False))
    plan_batch(topos, k, options=EngineOptions(dtype=jnp.float64))

Unknown or misspelled fields fail immediately in the ``EngineOptions``
constructor (with a did-you-mean hint via :func:`resolve_options`), and a
frozen instance hashes/compares by value, so it can key jit caches
directly. The old kwargs spelling had a one-release deprecation window
(PR 4) and is now **removed**: :func:`resolve_options` raises a
``TypeError`` naming the migration. CI keeps the
``-W error::DeprecationWarning`` job as the guard that no new deprecated
spellings creep into the planner surface.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Options consumed by ``solve_forest`` / ``solve_batch`` and everything
    layered on top (``solve_congestion``, ``plan`` / ``plan_batch``).

    dtype:        DP table dtype (float32 default; pass ``jnp.float64``
                  under ``jax_enable_x64`` for exactness on arbitrary rates)
    use_pallas:   None = auto (Pallas level-fold kernel on TPU, fused jnp
                  elsewhere); True/False forces a backend
    interpret:    run the Pallas kernel body in Python (CPU validation)
    cap:          min(k, subtree) per-level budget-width truncation
    color:        False = costs-only mode (no traceback, no masks)
    debug_tables: full-table pullback + host-numpy color (PR 1 path)
    """

    dtype: Any = jnp.float32
    use_pallas: bool | None = None
    interpret: bool = False
    cap: bool = True
    color: bool = True
    debug_tables: bool = False

    def replace(self, **changes) -> "EngineOptions":
        """A copy with ``changes`` applied (validated like the ctor)."""
        return dataclasses.replace(self, **changes)


_FIELDS = tuple(f.name for f in dataclasses.fields(EngineOptions))

_REMOVED = (
    "engine options are no longer accepted as keyword arguments "
    "({names}) — the PR-4 deprecation window has closed; pass "
    "options=EngineOptions({example}) instead"
)


def resolve_options(options: EngineOptions | None,
                    engine_kw: dict,
                    where: str) -> EngineOptions:
    """Validate the ``options=`` spelling at the call boundary.

    * ``options`` alone → returned as-is (defaults when None);
    * any stray keyword argument → ``TypeError`` *here*, at the call
      boundary: a misspelled option gets a did-you-mean hint, a known
      field name gets the ``options=EngineOptions(...)`` migration (the
      PR-4 kwargs shim is gone);
    * both at once → ``TypeError`` (ambiguous precedence is never guessed).
    """
    if not engine_kw:
        if options is None:
            return EngineOptions()
        if not isinstance(options, EngineOptions):
            raise TypeError(f"{where}: options must be an EngineOptions, "
                            f"got {type(options).__name__}")
        return options
    if options is not None:
        raise TypeError(
            f"{where}: got both options= and legacy engine keyword "
            f"arguments {sorted(engine_kw)} — pass everything through "
            "options=EngineOptions(...)")
    unknown = [k for k in engine_kw if k not in _FIELDS]
    if unknown:
        hints = []
        for k in unknown:
            close = difflib.get_close_matches(k, _FIELDS, n=1)
            hints.append(f"{k!r}" + (f" (did you mean {close[0]!r}?)"
                                     if close else ""))
        raise TypeError(
            f"{where}: unknown engine option(s) {', '.join(hints)}; "
            f"valid options: {', '.join(_FIELDS)}")
    raise TypeError(f"{where}: " + _REMOVED.format(
        names=", ".join(sorted(engine_kw)),
        example=", ".join(f"{k}=..." for k in sorted(engine_kw))))
