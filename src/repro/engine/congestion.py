"""Congestion-aware multi-tenant placement: a repeated-solve driver.

SOAR (and :func:`repro.engine.solve_batch`) minimizes each tenant's *own*
utilization; with T tenants on one shared reduction tree the independently
optimal placements pile messages onto the same links. Following the
congestion objective of Segal et al. 2022 (*Constrained In-network
Computing with Low Congestion in Datacenter Networks*), this driver
minimizes the **max-link congestion**

    C_max = max_e sum_t msg_e^t        (optionally time-weighted by rho_e)

by iterated penalty reweighting on top of the device-resident engine:

  1. solve all T tenants batched — one :func:`~repro.engine.solve_forest`
     call; same tree shape every round, so the layout-bucketed Forest maps
     every round onto **one** compiled executable;
  2. measure per-link traffic from the blue masks with the batched
     level sweep :func:`repro.core.congestion.messages_up_forest`
     (bit-identical to the host ``messages_up``);
  3. multiplicatively boost each tenant's *effective* rho on overloaded
     links, proportionally to that tenant's own contribution — the tenants
     responsible for a hotspot are the ones re-routed away from it; a
     deterministic per-tenant penalty gradient (``alpha_t`` ramps with the
     tenant index) breaks ties between look-alike tenants, so identical
     workloads spread instead of migrating in lockstep;
  4. re-solve on the reweighted rho and keep the best placement seen
     (lexicographically: max congestion, then total utilization — the loop
     is monotone-best, never worse than the utilization-only baseline).

Weights are quantized to a dyadic grid (multiples of ``1/1024``), so on
dyadic-rho trees every round's effective rho stays exactly representable
in float32 and the batched solve is **bit-identical** to the serial
:func:`repro.core.soar.soar` on the same reweighted instance (asserted in
``tests/test_congestion.py``). Utilization and congestion are always
reported against the *original* rho — the penalties shape the search, not
the objective.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.congestion import (congestion_profile, measure_fleet,
                               messages_up_forest)
from ..core.forest import build_forest
from ..core.tree import Tree
from .batched import solve_forest

#: weights are rounded to this dyadic grid so effective rho stays exactly
#: float32-representable on dyadic-rho trees (bit-identical engine/serial)
W_QUANTUM = 1.0 / 1024.0


@dataclasses.dataclass
class CongestionResult:
    """Best placement found by :func:`solve_congestion` plus diagnostics."""

    blue: np.ndarray          # (T, n) bool — best per-tenant masks
    costs: np.ndarray         # (T,) float64 — utilization on the ORIGINAL rho
    msgs: np.ndarray          # (T, n) int64 per-tenant per-link messages
    congestion: np.ndarray    # (n,) per-link congestion of the best round
    max_congestion: float     # C_max of the best round
    mean_congestion: float    # mean over links carrying traffic
    baseline_max: float       # round 0 = utilization-only solve_batch
    baseline_mean: float
    rounds: int               # solve rounds actually run (incl. round 0)
    best_round: int
    history: list             # per-round C_max
    rounds_log: list | None = None   # [(rho_eff (T,n), blue (T,n))] when
                                     # record_rounds=True (parity testing)

    @property
    def improvement(self) -> float:
        """Relative max-congestion reduction vs the utilization-only plan."""
        if self.baseline_max <= 0:
            return 0.0
        return 1.0 - self.max_congestion / self.baseline_max


def _quantize(w: np.ndarray, cap: float) -> np.ndarray:
    return np.minimum(np.round(w / W_QUANTUM) * W_QUANTUM, cap)


def solve_congestion(
    tree: Tree,
    loads: Sequence[np.ndarray],
    k: int,
    avail: Sequence[np.ndarray | None] | np.ndarray | None = None,
    *,
    max_rounds: int = 8,
    patience: int = 2,
    alpha: float = 2.0,
    hot_frac: float = 0.75,
    w_cap: float = 8.0,
    rho_weighted: bool = False,
    record_rounds: bool = False,
    **engine_kw,
) -> CongestionResult:
    """Minimize max-link congestion for T tenants sharing ``tree``.

    ``loads``: one (n,) load vector per tenant. ``avail``: a single mask
    shared by all tenants, a per-tenant sequence, or None. ``alpha``
    scales the penalty (each tenant t uses a deterministic ramp
    ``alpha * (1 + t/(T-1))`` — the symmetry breaker for identical
    tenants); links hotter than ``hot_frac * C_max`` are penalized;
    per-link weights are capped at ``w_cap`` and quantized to
    :data:`W_QUANTUM`. ``rho_weighted=True`` measures congestion in
    transmission time (``msg * rho``) instead of raw message counts.
    Engine keywords (``dtype``, ``use_pallas``, ``cap``, …) pass through
    to :func:`~repro.engine.solve_forest`. Runs at most ``max_rounds``
    solves, stopping early after ``patience`` rounds without improvement;
    the returned placement is the best round seen, so the result is never
    worse than the utilization-only baseline (round 0).
    """
    T = len(loads)
    if T == 0:
        raise ValueError("solve_congestion needs at least one tenant")
    if max_rounds < 1:
        raise ValueError("max_rounds must be >= 1")
    if not engine_kw.get("color", True):
        raise ValueError("solve_congestion needs blue masks; color=False "
                         "(costs-only mode) is not usable here")
    n = tree.n
    rho0 = tree.rho
    cong_w = rho0 if rho_weighted else None
    if avail is None or isinstance(avail, np.ndarray):
        avails = [avail] * T
    else:
        avails = list(avail)
        if len(avails) != T:
            raise ValueError(f"{len(avails)} avail masks for {T} tenants")
    # per-tenant penalty ramp: deterministic symmetry breaker
    alpha_t = alpha * (1.0 + (np.arange(T) / max(1, T - 1)))[:, None]

    w = np.ones((T, n))
    best = None                       # (cmax, total_util, round, state...)
    history: list[float] = []
    rounds_log: list | None = [] if record_rounds else None
    prof0 = None                      # round-0 per-link profile (baseline)
    stale = 0
    rounds = 0
    for r in range(max_rounds):
        if r == 0:
            trees = [tree] * T
            rho_eff = np.broadcast_to(rho0, (T, n))
        else:
            rho_eff = rho0[None, :] * w
            trees = [Tree(tree.parent, rho_eff[t]) for t in range(T)]
        f = build_forest(trees, list(loads), avails)
        res = solve_forest(f, k, **engine_kw)
        blue = res.blue[:, :n].copy()
        msgs = messages_up_forest(f, res.blue)[:, :n]
        prof = congestion_profile(msgs, cong_w)
        cmax = float(prof.max())
        util = (msgs * rho0).sum(axis=1).astype(np.float64)
        history.append(cmax)
        rounds = r + 1
        if r == 0:
            prof0 = prof
        if record_rounds:
            rounds_log.append((np.array(rho_eff, np.float64), blue.copy()))
        key = (cmax, float(util.sum()))
        if best is None or key < best[0]:
            best = (key, r, blue)
            stale = 0
        else:
            stale += 1
        if cmax == 0 or stale >= patience:
            break
        # penalty reweight: boost each tenant's effective rho on hot links
        # in proportion to that tenant's own traffic share of the hotspot
        hot = prof >= hot_frac * cmax
        contrib = (msgs * cong_w if cong_w is not None else msgs) / cmax
        boost = 1.0 + alpha_t * np.where(hot[None, :], contrib, 0.0)
        w = _quantize(w * boost, w_cap)

    _, best_round, blue = best
    # the reported statistics come from the one shared measurement recipe
    # (measure_fleet — same code path the orchestrator's post-admission
    # re-measure uses); its host sweep is bit-identical to the device
    # messages the loop tracked, so nothing shifts in the hand-off
    m = measure_fleet(tree, list(loads), list(blue), rho_weighted)
    base0 = prof0[prof0 > 0]
    return CongestionResult(
        blue=blue, costs=m.costs, msgs=m.msgs, congestion=m.congestion,
        max_congestion=m.max_congestion,
        mean_congestion=m.mean_congestion,
        baseline_max=float(history[0]),
        baseline_mean=float(base0.mean()) if base0.size else 0.0,
        rounds=rounds, best_round=best_round, history=history,
        rounds_log=rounds_log)
