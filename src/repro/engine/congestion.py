"""Congestion-aware multi-tenant placement: a device-resident penalty loop.

SOAR (and :func:`repro.engine.solve_batch`) minimizes each tenant's *own*
utilization; with T tenants sharing reduction trees the independently
optimal placements pile messages onto the same links. Following the
congestion objective of Segal et al. 2022 (*Constrained In-network
Computing with Low Congestion in Datacenter Networks*), this driver
minimizes the **max-link congestion**

    C_max = max_e sum_t msg_e^t        (optionally time-weighted by rho_e)

by iterated penalty reweighting of the engine's effective link rates:

  1. solve all T tenants batched against the current per-tenant effective
     rho — the packed rho-up table is rebuilt *on device* from the scaled
     edge rates (:func:`~repro.kernels.minplus.levelfold.rho_up_from_edges`),
     so every round reuses one prebuilt Forest and one compiled gather /
     color executable;
  2. measure per-link traffic from the blue masks with the batched level
     sweep (``repro.core.congestion``) — still on device;
  3. multiplicatively boost each tenant's effective rho on overloaded
     links, proportionally to that tenant's own contribution — the tenants
     responsible for a hotspot are the ones re-routed away from it; a
     deterministic per-tenant penalty gradient (``alpha_t`` ramps with the
     tenant index) breaks ties between look-alike tenants, so identical
     workloads spread instead of migrating in lockstep. With per-switch
     ``capacity`` given, links whose switch is near its capacity claim are
     priced up jointly with hot links (capacity pricing);
  4. re-solve on the reweighted rho and keep the best (strictly lowest
     C_max) placement seen — the loop is monotone-best, never worse than
     the utilization-only baseline (round 0).

**Fleet-native.** The driver is :func:`solve_fleet`: T tenants spread over
N aggregation trees that hang off a shared core of C extra links
(:class:`repro.collectives.topology.Fleet`). Every round profiles and
reweights over the *union* of tree-local and shared-core links inside the
same loop: per-tree profiles come from a tenant->tree scatter-add, core
profiles from each tenant's root-crossing count summed over the tenants
whose core path includes the link, and the core penalty weights feed back
into the DP as an *additive* extension of each tenant's root up-edge
(core hops are in series with the root hop — see
:func:`~repro.kernels.minplus.levelfold.scaled_edges`). That is how
tenants on *different* trees get congestion-coupled: a hot shared core
link raises every crossing tenant's effective root rate, and the DP pulls
their aggregation points rootward until the core cools.
:func:`solve_congestion` is the single-tree entry — structurally the
degenerate ``N=1, C=0`` fleet (one tree, no core), not a parallel code
path, which is what keeps it bit-identical to the fleet machinery.

**Device-resident loop (default).** ``device_loop=True`` runs the whole
round loop as one jitted ``lax.while_loop``: fused level-fold gather →
on-device color → messages-up sweep → penalty reweight → monotone-best
tracking, with nothing leaving the accelerator between rounds. Only the
best round's masks, the scalar congestion history, and the round-0 profile
transfer at the end (``CongestionResult.bytes_to_host`` reports the
traffic). ``device_loop=False`` keeps the host-driven reference: the same
jitted round pieces called one round at a time through the public
:func:`~repro.engine.solve_forest` ``rho_scale`` / ``rho_root_add``
API, with masks, counts and the profile pulled to the host every round
(PR 3's transfer pattern).

**In-loop hard admission.** ``residual=`` hands the driver per-tree
residual-capacity vectors (the orchestrator's integer claim ledgers) and
turns capacity from a *price* into a *constraint inside the loop*: every
round, each tenant's candidate blue set is truncated to the claims the
residual actually covers (claims are ranked per switch in tenant order —
exactly the order a host ledger would replay them) and the rejected
(tenant, switch) pairs are *banned* through the existing ``avail``
mechanics, so the next round's DP routes those tenants elsewhere. The
loop therefore converges directly to placements a per-switch ledger can
admit wholesale — no host round-trip per admission, no post-hoc
eviction. The device loop computes claim ranks with an exact integer
one-hot cumsum; the host reference replays a literal sequential numpy
ledger per round — integer arithmetic both ways, so the two paths stay
round-for-round bit-identical (``tests/test_admission_device.py``).

**Parity.** Both paths run the *identical* float32 update arithmetic —
the shared :func:`_round_penalty` body (profiles + reweights for tree and
core links), the shared
:func:`~repro.kernels.minplus.levelfold.scaled_edges` effective-edge
recipe and the shared device rho-up recompute — so with
``record_rounds=True`` the two paths are round-for-round bit-identical:
same effective rho, same masks, same history (asserted in
``tests/test_congestion_device.py`` and ``tests/test_fleet.py``). Weights
are quantized to a dyadic grid (multiples of ``1/1024``), so on
dyadic-rho trees every round's effective rho stays exactly representable
in float32 and the batched solve is also bit-identical to the serial
:func:`repro.core.soar.soar` on the same reweighted instance (asserted in
``tests/test_congestion.py``). Utilization and congestion are always
reported against the *original* rho — the penalties shape the search, not
the objective.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.congestion import _messages_body, measure_fleet_multi
from ..core.forest import build_fleet_forest, build_forest
from ..core.tree import Tree
from ..kernels.minplus.levelfold import rho_up_from_edges, scaled_edges
from .batched import (_color_body, _device_inputs, _gather_packed,
                      _override_inputs)
from .options import EngineOptions, resolve_options

#: weights are rounded to this dyadic grid so effective rho stays exactly
#: float32-representable on dyadic-rho trees (bit-identical engine/serial)
W_QUANTUM = 1.0 / 1024.0


@dataclasses.dataclass
class CongestionResult:
    """Best placement found by :func:`solve_fleet` plus diagnostics.

    Per-link arrays use the fleet's **global link-id space**: tree g's
    up-links occupy ``[off_g, off_g + n_g)`` of ``congestion`` (offsets
    in tree order), the C shared-core links fill the final entries (also
    broken out as ``core_congestion``). For the single-tree
    :func:`solve_congestion` entry that is simply the familiar ``(n,)``
    per-link profile.
    """

    blue: np.ndarray          # (T, max_g n_g) bool — best per-tenant masks,
                              # each row valid on its own tree's prefix
    costs: np.ndarray         # (T,) float64 — utilization on the ORIGINAL rho
    msgs: np.ndarray          # (T, max_g n_g) int64 tree-local messages
    congestion: np.ndarray    # (sum n_g + C,) global per-link profile of
                              # the best round
    max_congestion: float     # C_max of the best round (incl. core links)
    mean_congestion: float    # mean over links carrying traffic
    baseline_max: float       # round 0 = utilization-only solve_batch
    baseline_mean: float
    rounds: int               # solve rounds actually run (incl. round 0)
    best_round: int
    history: list             # per-round C_max
    rounds_log: list | None = None   # [(rho_eff (T,n), blue (T,n))] when
                                     # record_rounds=True (parity testing)
    bytes_to_host: int = 0    # device->host traffic the driver actually paid
    tree_of: np.ndarray | None = None    # (T,) tenant -> tree index
    core_congestion: np.ndarray | None = None  # (C,) shared-core profile
    # -- hard admission (residual=...) only --
    admission_dropped: np.ndarray | None = None  # (T,) int64 claims the
                                                 # best round could not admit
    residual_after: list | None = None   # per-tree int64 residual ledgers
                                         # after the best round's claims
    admission_log: list | None = None    # per-round (T,) dropped-claim
                                         # counts when record_rounds=True

    @property
    def improvement(self) -> float:
        """Relative max-congestion reduction vs the utilization-only plan."""
        if self.baseline_max <= 0:
            return 0.0
        return 1.0 - self.max_congestion / self.baseline_max


# ---------------------------------------------------------------------------
# shared round arithmetic — the single definition BOTH loop flavors run.
# The device while_loop inlines these; the host reference calls the jitted
# _penalty_step wrapper below. Same traced op sequence -> same float32
# results (XLA does not contract or reassociate elementwise float ops),
# which is what makes the two paths round-for-round bit-identical. Keep it
# that way.
# ---------------------------------------------------------------------------

def _profile(msgs: jax.Array, link_w: jax.Array, tree_id: jax.Array,
             *, n_trees: int) -> jax.Array:
    """Per-tree per-link congestion: int32 counts scatter-added over each
    tree's tenants, then weighted (``link_w`` is (N, links) — the original
    per-link rho when rho_weighted, else 1). Integer scatter-add is exact
    and order-free, so the N=1 case equals the plain tenant sum bitwise."""
    counts = jnp.zeros((n_trees, msgs.shape[1]),
                       msgs.dtype).at[tree_id].add(msgs)
    return counts.astype(link_w.dtype) * link_w


def _crowding(blue: jax.Array, tree_id: jax.Array, capacity: jax.Array,
              cap_frac, *, n_trees: int) -> jax.Array:
    """Capacity-pricing term: per-tenant (T, links) pressure on crowded
    switches of the tenant's own tree (zero elsewhere)."""
    counts = jnp.zeros((n_trees, blue.shape[1]),
                       jnp.int32).at[tree_id].add(blue.astype(jnp.int32))
    usage = jnp.take(counts, tree_id, axis=0).astype(capacity.dtype)
    pressure = usage / jnp.maximum(jnp.take(capacity, tree_id, axis=0), 1e-6)
    crowded = (pressure >= cap_frac) & blue
    return jnp.where(crowded, pressure, 0.0)


def _reweight(w, msgs, prof_t, cmax, alpha_t, ramp_t, hot_frac, w_cap,
              link_w_t, crowd, cap_beta, *, priced: bool):
    """One penalty update of a (T, links) weight matrix.

    Hot links (``prof_t >= hot_frac * cmax`` — C_max is the *global* max,
    over tree and core links jointly) boost each tenant's weight in
    proportion to that tenant's own traffic share; ``crowd`` carries the
    capacity-pricing pressure (:func:`_crowding`) when ``priced``. One
    dyadic quantization after the joint boost keeps the effective rho
    exactly float32-representable on dyadic trees.
    """
    hot = prof_t >= hot_frac * cmax
    contrib = msgs.astype(w.dtype) * link_w_t / cmax
    boost = 1.0 + alpha_t * jnp.where(hot, contrib, 0.0)
    if priced:
        boost = boost * (1.0 + cap_beta * ramp_t * crowd)
    q = jnp.round(w * boost / W_QUANTUM) * W_QUANTUM
    return jnp.minimum(q, w_cap)


def _core_extra(core_base: jax.Array, wc: jax.Array,
                core_onf: jax.Array) -> jax.Array:
    """Per-tenant additive root-edge extension from shared-core transit:
    each core link on the tenant's path contributes its penalty-weighted
    rate. ``core_base``: (C,) core rho; ``wc``: (T, C) weights;
    ``core_onf``: (T, C) float incidence. Returns (T,)."""
    return (core_base[None, :] * wc * core_onf).sum(axis=1)


def _admit_ranked(blue, tree_id, residual, *, n_trees: int):
    """Hard-admission truncation of one round's candidate blue sets.

    A claim by tenant t on switch s is admitted iff fewer than
    ``residual[tree_of[t], s]`` lower-indexed tenants of the same tree
    also claim s this round — the exact set a sequential per-tree ledger
    replay in tenant order admits, computed in one shot as an integer
    one-hot cumsum (exact and order-free per element, so the device loop
    and the host ledger reference agree bitwise). Returns
    ``(admitted, rejected)`` bool (T, links) masks.
    """
    oh = (tree_id[:, None] == jnp.arange(n_trees)[None, :]).astype(jnp.int32)
    cum = jnp.cumsum(blue.astype(jnp.int32)[:, None, :] * oh[:, :, None],
                     axis=0)                       # (T, N, links)
    rank = (cum * oh[:, :, None]).sum(axis=1)      # own-tree row, (T, links)
    res_t = jnp.take(residual, tree_id, axis=0)
    admitted = blue & (rank <= res_t)
    return admitted, blue & ~admitted


def _round_penalty(w, wc, msgs, blue, root_idx, tree_id, link_w,
                   core_link_w, core_on, capacity, alpha_t, ramp_t,
                   hot_frac, w_cap, cap_beta, cap_frac, *,
                   n_trees: int, priced: bool):
    """Profile the union of tree-local and shared-core links, then apply
    one penalty update to both weight matrices.

    ``msgs``: (T, links) int32 per-tenant counts on the tenant's own tree;
    ``root_idx``: (T,) column of each tenant's root link (its root-crossing
    count is the core transit); ``core_on``: (T, C) bool incidence.
    Returns ``(prof_tree (N, links), prof_core (C,), cmax, w', wc')`` —
    C_max is the max over *all* links, tree and core jointly, so a hot
    shared core link dominates the stop/best tracking and the hot-link
    threshold exactly like a hot tree link.
    """
    prof_tree = _profile(msgs, link_w, tree_id, n_trees=n_trees)
    cmax = prof_tree.max()
    C = wc.shape[1]
    if C:
        root_msgs = jnp.take_along_axis(msgs, root_idx[:, None], axis=1)
        core_msgs = root_msgs * core_on.astype(msgs.dtype)      # (T, C)
        prof_core = (core_msgs.sum(axis=0).astype(core_link_w.dtype)
                     * core_link_w)
        cmax = jnp.maximum(cmax, prof_core.max())
    else:
        prof_core = jnp.zeros((0,), w.dtype)
    prof_t = jnp.take(prof_tree, tree_id, axis=0)               # (T, links)
    link_w_t = jnp.take(link_w, tree_id, axis=0)
    crowd = (_crowding(blue, tree_id, capacity, cap_frac, n_trees=n_trees)
             if priced else jnp.zeros_like(w))
    w2 = _reweight(w, msgs, prof_t, cmax, alpha_t, ramp_t, hot_frac, w_cap,
                   link_w_t, crowd, cap_beta, priced=priced)
    if C:
        # the core links have no per-switch capacity claim — pricing is a
        # tree-link concept — so their reweight is never priced
        wc2 = _reweight(wc, core_msgs,
                        jnp.broadcast_to(prof_core[None, :], wc.shape),
                        cmax, alpha_t, ramp_t, hot_frac, w_cap,
                        jnp.broadcast_to(core_link_w[None, :], wc.shape),
                        jnp.zeros_like(wc), cap_beta, priced=False)
    else:
        wc2 = wc
    return prof_tree, prof_core, cmax, w2, wc2


_penalty_step = functools.partial(
    jax.jit, static_argnames=("n_trees", "priced"))(_round_penalty)

_core_extra_step = jax.jit(_core_extra)


@jax.jit
def _edge_scale(base_edge: jax.Array, w: jax.Array) -> jax.Array:
    """Effective per-edge rates (the quantity ``record_rounds`` logs)."""
    return scaled_edges(base_edge, w)


@jax.jit
def _edge_scale_core(base_edge: jax.Array, w: jax.Array, extra: jax.Array,
                     root_idx: jax.Array) -> jax.Array:
    """:func:`_edge_scale` with the shared-core root extension applied."""
    return scaled_edges(base_edge, w, extra, root_idx)


# ---------------------------------------------------------------------------
# the device-resident loop
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("lvl_off", "lvl_width", "lvl_internal", "lvl_sub", "k",
                     "cap", "use_pallas", "interpret", "max_rounds",
                     "record", "priced", "admit", "n_trees"))
def _device_driver(
    kid, load, send, avail, par, cidx, root_slot,     # packed solve inputs
    base_edge, anc, valid,                            # rho-override inputs
    tree_id, link_w, capacity,                        # (T,), (N,S), (N,S)
    residual,                                         # (N,S) int32 ledgers
    core_base, core_on, core_link_w,                  # (C,), (T,C), (C,)
    alpha_t, ramp_t,                                  # (T, 1) tenant ramps
    hot_frac, w_cap, cap_beta, cap_frac, patience,    # scalars
    *,
    lvl_off, lvl_width, lvl_internal, lvl_sub, k, cap, use_pallas,
    interpret, max_rounds: int, record: bool, priced: bool, admit: bool,
    n_trees: int,
):
    """The whole penalty loop as one ``lax.while_loop`` on the accelerator.

    Per round: shared-core root extension + device rho-up recompute ->
    fused level-fold gather -> on-device color (slot-indexed masks, no
    node gather) -> messages-up sweep -> shared profile/reweight over the
    union of tree and core links -> monotone-best tracking. The carry
    holds both weight matrices (tree links and core links), best-so-far
    masks, the scalar history and (when ``record``) the per-round logs;
    nothing crosses the host boundary until the caller pulls the final
    tuple.

    With ``admit`` the carry also owns the availability masks: each
    round's candidate blues are truncated to what ``residual`` covers
    (:func:`_admit_ranked`) and rejected claims ban their (tenant,
    switch) pair from every later round, so the loop converges to
    placements the per-switch ledgers admit outright. A round that
    banned something never triggers the patience stop — the search
    landscape just changed under it.
    """
    T, S, _ = kid.shape
    dt = base_edge.dtype
    C = core_base.shape[0]

    def body(carry):
        (r, w, wc, avail, stale, stop, best_cmax, best_blue, best_round,
         best_drop, history, prof0, prof0c, log_rho, log_blue,
         log_drop) = carry
        if C:
            extra = _core_extra(core_base, wc, core_on.astype(dt))
            edges = scaled_edges(base_edge, w, extra, root_slot)
        else:
            edges = scaled_edges(base_edge, w)
        R = rho_up_from_edges(edges, anc, valid)
        blocks = _gather_packed(
            kid, load, send, avail, R,
            lvl_off=lvl_off, lvl_width=lvl_width,
            lvl_internal=lvl_internal, lvl_sub=lvl_sub,
            k=k, cap=cap, use_pallas=use_pallas, interpret=interpret)
        blue, _ = _color_body(
            blocks, kid, par, cidx, load, send, avail, R, root_slot,
            lvl_off=lvl_off, lvl_width=lvl_width,
            lvl_internal=lvl_internal, lvl_sub=lvl_sub, k=k, cap=cap)
        if admit:
            blue, rejected = _admit_ranked(blue, tree_id, residual,
                                           n_trees=n_trees)
            avail = avail & ~rejected              # persistent in-loop ban
            banned = rejected.any()
            drop = rejected.sum(axis=1).astype(jnp.int32)
        else:
            banned = jnp.asarray(False)
            drop = jnp.zeros((T,), jnp.int32)
        msgs = _messages_body(
            kid, load, send, blue,
            lvl_off=lvl_off, lvl_width=lvl_width, lvl_internal=lvl_internal)
        prof_tree, prof_core, cmax, w2, wc2 = _round_penalty(
            w, wc, msgs, blue, root_slot, tree_id, link_w, core_link_w,
            core_on, capacity, alpha_t, ramp_t, hot_frac, w_cap, cap_beta,
            cap_frac, n_trees=n_trees, priced=priced)
        history = history.at[r].set(cmax)
        prof0 = jnp.where(r == 0, prof_tree, prof0)
        prof0c = jnp.where(r == 0, prof_core, prof0c)
        if record:
            log_rho = log_rho.at[r].set(edges)
            log_blue = log_blue.at[r].set(blue)
            log_drop = log_drop.at[r].set(drop)
        better = cmax < best_cmax                    # strict: earliest wins
        best_blue = jnp.where(better, blue, best_blue)
        best_round = jnp.where(better, r, best_round)
        best_cmax = jnp.where(better, cmax, best_cmax)
        best_drop = jnp.where(better, drop, best_drop)
        stale = jnp.where(better, 0, stale + 1)
        if admit:
            stop = (cmax == 0.0) | ((stale >= patience) & ~banned)
        else:
            stop = (cmax == 0.0) | (stale >= patience)
        return (r + 1, w2, wc2, avail, stale, stop, best_cmax, best_blue,
                best_round, best_drop, history, prof0, prof0c, log_rho,
                log_blue, log_drop)

    def cond(carry):
        return (carry[0] < max_rounds) & ~carry[5]

    Rl = max_rounds if record else 0
    init = (jnp.int32(0), jnp.ones((T, S), dt), jnp.ones((T, C), dt),
            avail, jnp.int32(0), jnp.asarray(False),
            jnp.asarray(jnp.inf, dt),
            jnp.zeros((T, S), bool), jnp.int32(0), jnp.zeros((T,), jnp.int32),
            jnp.full((max_rounds,), -1.0, dt), jnp.zeros((n_trees, S), dt),
            jnp.zeros((C,), dt),
            jnp.zeros((Rl, T, S), dt), jnp.zeros((Rl, T, S), bool),
            jnp.zeros((Rl, T), jnp.int32))
    out = jax.lax.while_loop(cond, body, init)
    (r, _, _, _, _, _, best_cmax, best_blue, best_round, best_drop, history,
     prof0, prof0c, log_rho, log_blue, log_drop) = out
    return best_blue, best_round, r, history, prof0, prof0c, best_drop, \
        log_rho, log_blue, log_drop


# ---------------------------------------------------------------------------
# the public drivers
# ---------------------------------------------------------------------------

def solve_fleet(
    trees: Sequence[Tree],
    loads: Sequence[np.ndarray],
    tree_of: Sequence[int],
    k: int,
    avail: Sequence[np.ndarray | None] | None = None,
    *,
    core_rho: np.ndarray | None = None,
    core_path: Sequence[Sequence[int]] | None = None,
    max_rounds: int = 8,
    patience: int = 2,
    alpha: float = 2.0,
    hot_frac: float = 0.75,
    w_cap: float = 8.0,
    rho_weighted: bool = False,
    capacity: Sequence[np.ndarray] | None = None,
    cap_beta: float = 1.0,
    cap_frac: float = 0.75,
    residual: Sequence[np.ndarray] | None = None,
    record_rounds: bool = False,
    device_loop: bool = True,
    options: EngineOptions | None = None,
    **engine_kw,
) -> CongestionResult:
    """Minimize max-link congestion for T tenants across a multi-tree fleet.

    ``trees``: the N distinct aggregation trees; ``tree_of[t]`` names
    tenant t's tree (every tree needs at least one tenant); ``loads``:
    one load vector per tenant, shaped for its own tree. ``core_rho`` /
    ``core_path`` describe the shared core (see
    :class:`repro.collectives.topology.Fleet`): a tenant's root-crossing
    messages transit every core link on its tree's path, the per-link
    profile spans the union of tree-local and core links, and core
    penalties feed back as additive root-edge extensions — tenants on
    different trees trade placements through the shared links.

    ``avail``: a per-tenant sequence of masks (or None). ``capacity``:
    per-*tree* capacity vectors (len N) switching on capacity pricing for
    tree links. ``residual``: per-*tree* integer residual-capacity
    ledgers (len N) switching on **hard in-loop admission** — every
    round's candidate blues are truncated to the claims the ledger
    covers, rejected claims ban their (tenant, switch) pair via the
    ``avail`` mechanics, and the returned placements are feasible against
    the ledgers wholesale (``admission_dropped`` / ``residual_after`` on
    the result report the best round's shortfall and remaining
    capacity). Zero-residual and zero-capacity switches leave every
    affected tenant's candidate set up front. All other knobs as
    :func:`solve_congestion`, which is the degenerate ``N=1, C=0`` call
    of this driver.
    """
    T = len(loads)
    if T == 0:
        raise ValueError("solve_fleet needs at least one tenant")
    if max_rounds < 1:
        raise ValueError("max_rounds must be >= 1")
    opts = resolve_options(options, engine_kw, "solve_fleet")
    if not opts.color:
        raise ValueError("solve_fleet needs blue masks; color=False "
                         "(costs-only mode) is not usable here")
    if opts.debug_tables:
        raise ValueError("solve_fleet re-solves on device-side effective "
                         "rho; the debug_tables host replay is not usable "
                         "here")
    # capacity-knob boundary validation: _crowding clamps capacity with
    # 1e-6 (a numerical guard, not a semantics), so malformed knobs must
    # die here, not price a zero-capacity switch as admittable
    if not (np.isfinite(cap_frac) and 0.0 < cap_frac <= 1.0):
        raise ValueError(f"cap_frac must be in (0, 1], got {cap_frac}")
    if not (np.isfinite(cap_beta) and cap_beta >= 0.0):
        raise ValueError(f"cap_beta must be finite and >= 0, "
                         f"got {cap_beta}")
    trees = list(trees)
    N = len(trees)
    tid_np = np.asarray(list(tree_of), np.int32)
    if tid_np.shape != (T,):
        raise ValueError(f"tree_of shape {tid_np.shape} != ({T},)")
    if avail is None:
        avails = [None] * T
    else:
        avails = list(avail)
        if len(avails) != T:
            raise ValueError(f"{len(avails)} avail masks for {T} tenants")
    priced = capacity is not None
    if priced:
        capacity = [np.asarray(c, np.float64) for c in capacity]
        if len(capacity) != N:
            raise ValueError(f"{len(capacity)} capacity vectors for "
                             f"{N} trees")
        for g, c in enumerate(capacity):
            if c.shape != (trees[g].n,):
                raise ValueError(f"capacity shape {c.shape} != "
                                 f"({trees[g].n},)")
            if not np.all(np.isfinite(c)) or np.any(c < 0):
                raise ValueError(f"capacity vector for tree {g} must be "
                                 "finite and non-negative")
    admit = residual is not None
    if admit:
        residual = [np.asarray(rg) for rg in residual]
        if len(residual) != N:
            raise ValueError(f"{len(residual)} residual ledgers for "
                             f"{N} trees")
        checked = []
        for g, rg in enumerate(residual):
            if rg.shape != (trees[g].n,):
                raise ValueError(f"residual shape {rg.shape} != "
                                 f"({trees[g].n},) for tree {g}")
            if (not np.all(np.isfinite(rg.astype(np.float64)))
                    or np.any(rg.astype(np.float64)
                              != np.floor(rg.astype(np.float64)))):
                raise ValueError(f"residual ledger for tree {g} must be "
                                 "integer-valued")
            if np.any(rg.astype(np.int64) < 0):
                raise ValueError(f"residual ledger for tree {g} must be "
                                 "non-negative")
            checked.append(rg.astype(np.int64))
        residual = checked
    if admit or priced:
        # hard-unavailability flows through the avail mechanics: switches
        # with no residual (or no capacity at all) leave their tree's
        # tenants' candidate sets before the first solve
        hard = [np.ones(tr.n, bool) for tr in trees]
        for g in range(N):
            if admit:
                hard[g] &= residual[g] > 0
            if priced:
                hard[g] &= capacity[g] > 0
        if not all(h.all() for h in hard):
            avails = [
                (hard[g].copy() if a is None
                 else np.asarray(a, bool) & hard[g])
                for a, g in zip(avails, tid_np)]
    if admit:
        # the host ledger replay mutates its per-tenant masks (persistent
        # bans) — every tenant needs its own materialized copy
        avails = [np.ones(trees[g].n, bool) if a is None
                  else np.array(a, dtype=bool, copy=True)
                  for a, g in zip(avails, tid_np)]
    use_pallas = opts.use_pallas
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    # one Forest, one packing, one compiled executable for the whole loop
    f, lay = build_fleet_forest(trees, list(loads), tid_np, avails,
                                core_rho=core_rho, core_path=core_path)
    C = lay.n_core
    dt = opts.dtype
    kid, load, send, avail_d, _, par, cidx, slot_d, root_d = \
        _device_inputs(f, dt)
    base_edge, anc, valid, _, _ = _override_inputs(f, dt)
    rep = lay.rep

    # per-tenant penalty ramp: deterministic symmetry breaker
    ramp_t = jnp.asarray(
        (1.0 + np.arange(T) / max(1, T - 1))[:, None], dt)
    alpha_t = jnp.asarray(alpha, dt) * ramp_t
    scal = dict(hot_frac=jnp.asarray(hot_frac, dt),
                w_cap=jnp.asarray(w_cap, dt),
                cap_beta=jnp.asarray(cap_beta, dt),
                cap_frac=jnp.asarray(cap_frac, dt))
    # per-tree node-indexed per-link constants (host reference) and their
    # slot-indexed twins (device loop) — same value per real link, so the
    # two paths' elementwise updates agree bitwise
    if rho_weighted:
        link_w_node = np.zeros((N, f.n_max))
        for g, tr in enumerate(trees):
            link_w_node[g, : tr.n] = tr.rho
        link_w_node = jnp.asarray(link_w_node, dt)
        link_w_slot = base_edge[jnp.asarray(rep)]          # (N, S)
        core_link_w = jnp.asarray(lay.core_rho, dt)
    else:
        link_w_node = jnp.ones((N, f.n_max), dt)
        link_w_slot = jnp.ones((N, f.n_slots), dt)
        core_link_w = jnp.ones((C,), dt)
    cap_node = np.ones((N, f.n_max))
    cap_slot = np.ones((N, f.n_slots))
    if priced:
        for g in range(N):
            cap_node[g, : trees[g].n] = capacity[g]
            sn_g = f.slot_node[rep[g]]
            cap_slot[g] = np.where(sn_g >= 0,
                                   cap_node[g][np.maximum(sn_g, 0)], 1.0)
    cap_node = jnp.asarray(cap_node, dt)
    cap_slot = jnp.asarray(cap_slot, dt)
    # residual ledger twins (node for the host replay, slot for the device
    # rank truncation) — padding slots read T so they can never reject
    res_slot_np = np.full((N, f.n_slots), T, np.int64)
    if admit:
        res_node_np = np.zeros((N, f.n_max), np.int64)
        for g in range(N):
            res_node_np[g, : trees[g].n] = residual[g]
            sn_g = f.slot_node[rep[g]]
            res_slot_np[g] = np.where(
                sn_g >= 0, res_node_np[g][np.maximum(sn_g, 0)], T)
    res_slot = jnp.asarray(res_slot_np, jnp.int32)
    tree_id = jnp.asarray(lay.tree_of)
    core_base = jnp.asarray(lay.core_rho, dt)              # (C,)
    core_on = jnp.asarray(lay.core_inc)                    # (T, C) bool

    if device_loop:
        state = _run_device(f, lay, k, opts, use_pallas, kid, load, send,
                            avail_d, par, cidx, root_d, base_edge, anc,
                            valid, tree_id, link_w_slot, cap_slot, res_slot,
                            core_base, core_on, core_link_w, alpha_t,
                            ramp_t, scal, patience, max_rounds,
                            record_rounds, priced, admit)
    else:
        state = _run_host(trees, loads, tid_np, avails, f, lay, k, opts,
                          link_w_node, cap_node, residual, core_base,
                          core_on, core_link_w, alpha_t, ramp_t, scal,
                          patience, max_rounds, record_rounds, priced,
                          admit)
    (blue_node, best_round, rounds, history, prof0_node, prof0_core,
     rounds_log, bytes_to_host, best_drop, admission_log) = state

    n_big = int(lay.tree_n.max())
    blue = blue_node[:, :n_big]
    # the reported statistics come from the one shared measurement recipe
    # (measure_fleet_multi — same code path the orchestrator's
    # post-admission re-measure uses); its host sweep is bit-identical to
    # the device messages the loop tracked, so nothing shifts in the
    # hand-off
    m = measure_fleet_multi(
        trees, tid_np, list(loads),
        [blue[t, : trees[int(tid_np[t])].n] for t in range(T)],
        core_rho=lay.core_rho if C else None,
        core_path=lay.core_path if C else None,
        rho_weighted=rho_weighted)
    parts = [prof0_node[g, : trees[g].n] for g in range(N)]
    if C:
        parts.append(prof0_core)
    base0 = np.concatenate(parts)
    base0 = base0[base0 > 0]
    admission_dropped = residual_after = None
    if admit:
        admission_dropped = np.asarray(best_drop, np.int64)
        residual_after = []
        for g in range(N):
            claims = np.zeros(trees[g].n, np.int64)
            for t in range(T):
                if int(tid_np[t]) == g:
                    claims += blue[t, : trees[g].n].astype(np.int64)
            residual_after.append(residual[g] - claims)
    return CongestionResult(
        blue=blue, costs=m.costs, msgs=m.msgs, congestion=m.congestion,
        max_congestion=m.max_congestion,
        mean_congestion=m.mean_congestion,
        baseline_max=float(history[0]),
        baseline_mean=float(base0.astype(np.float64).mean())
        if base0.size else 0.0,
        rounds=rounds, best_round=best_round, history=history,
        rounds_log=rounds_log, bytes_to_host=bytes_to_host,
        tree_of=tid_np.copy(), core_congestion=m.core_congestion,
        admission_dropped=admission_dropped, residual_after=residual_after,
        admission_log=admission_log)


def solve_congestion(
    tree: Tree,
    loads: Sequence[np.ndarray],
    k: int,
    avail: Sequence[np.ndarray | None] | np.ndarray | None = None,
    *,
    max_rounds: int = 8,
    patience: int = 2,
    alpha: float = 2.0,
    hot_frac: float = 0.75,
    w_cap: float = 8.0,
    rho_weighted: bool = False,
    capacity: np.ndarray | None = None,
    cap_beta: float = 1.0,
    cap_frac: float = 0.75,
    residual: np.ndarray | None = None,
    record_rounds: bool = False,
    device_loop: bool = True,
    options: EngineOptions | None = None,
    **engine_kw,
) -> CongestionResult:
    """Minimize max-link congestion for T tenants sharing ``tree``.

    ``loads``: one (n,) load vector per tenant. ``avail``: a single mask
    shared by all tenants, a per-tenant sequence, or None. ``alpha``
    scales the penalty (each tenant t uses a deterministic ramp
    ``alpha * (1 + t/(T-1))`` — the symmetry breaker for identical
    tenants); links hotter than ``hot_frac * C_max`` are penalized;
    per-link weights are capped at ``w_cap`` and quantized to
    :data:`W_QUANTUM`. ``rho_weighted=True`` measures congestion in
    transmission time (``msg * rho``) instead of raw message counts.

    ``capacity`` (n,) switches on *capacity pricing*: links whose switch
    has blue claims from at least ``cap_frac`` of its per-switch capacity
    this round are priced up (factor ``1 + cap_beta * ramp_t *
    usage/capacity``) jointly with the hot-link boost, for the tenants
    sitting on them — steering the fleet away from switches the
    orchestrator is about to run out of.

    ``residual`` (n,) switches on **hard in-loop admission**: an integer
    per-switch claim ledger the returned placements are guaranteed
    feasible against — every round's candidate blues are truncated to the
    claims the ledger covers (in tenant order, exactly a sequential
    ledger replay) and rejected (tenant, switch) pairs are banned for the
    rest of the loop. ``admission_dropped`` / ``residual_after`` on the
    result report the best round's shortfall and remaining capacity.

    ``device_loop=True`` (default) runs the whole loop on the
    accelerator (one jitted ``lax.while_loop``; O(1) host transfer
    total); ``device_loop=False`` is the host-driven parity reference —
    identical arithmetic, per-round transfers (see module docstring).
    Engine behavior comes from ``options=EngineOptions(...)``;
    ``color=False`` and ``debug_tables=True`` are rejected — the driver
    needs on-device masks. Runs at most ``max_rounds`` solves, stopping
    early after ``patience`` rounds without improvement; the returned
    placement is the best round seen, so the result is never worse than
    the utilization-only baseline (round 0).

    This IS the fleet driver: structurally the degenerate single-tree,
    no-core call of :func:`solve_fleet` — same packing, same loop, same
    arithmetic — which is what keeps the two bit-identical.
    """
    T = len(loads)
    if T == 0:
        raise ValueError("solve_congestion needs at least one tenant")
    # resolve here so errors cite the entry point the caller actually used
    opts = resolve_options(options, engine_kw, "solve_congestion")
    n = tree.n
    if avail is None or isinstance(avail, np.ndarray):
        avails = [avail] * T
    else:
        avails = list(avail)
        if len(avails) != T:
            raise ValueError(f"{len(avails)} avail masks for {T} tenants")
    if capacity is not None:
        capacity = np.asarray(capacity, np.float64)
        if capacity.shape != (n,):
            raise ValueError(f"capacity shape {capacity.shape} != ({n},)")
        capacity = [capacity]
    if residual is not None:
        residual = np.asarray(residual)
        if residual.shape != (n,):
            raise ValueError(f"residual shape {residual.shape} != ({n},)")
        residual = [residual]
    return solve_fleet(
        [tree], loads, [0] * T, k, avails,
        max_rounds=max_rounds, patience=patience, alpha=alpha,
        hot_frac=hot_frac, w_cap=w_cap, rho_weighted=rho_weighted,
        capacity=capacity, cap_beta=cap_beta, cap_frac=cap_frac,
        residual=residual, record_rounds=record_rounds,
        device_loop=device_loop, options=opts)


def _slots_to_nodes_np(x_slot: np.ndarray, f, rows=None) -> np.ndarray:
    """Host twin of the engine's slot->node gather (padding reads 0).

    ``rows`` selects which batch rows' ``slot_of`` maps apply — the fleet
    driver maps its (N, S) per-tree profiles through each tree's
    representative tenant row.
    """
    slot_of = f.slot_of if rows is None else f.slot_of[rows]
    B = x_slot.shape[0]
    pad = np.concatenate(
        [x_slot, np.zeros((B, 1), x_slot.dtype)], axis=1)
    return np.take_along_axis(pad, slot_of, axis=1)


def _run_device(f, lay, k, opts, use_pallas, kid, load, send, avail_d, par,
                cidx, root_d, base_edge, anc, valid, tree_id, link_w_slot,
                cap_slot, res_slot, core_base, core_on, core_link_w, alpha_t,
                ramp_t, scal, patience, max_rounds, record_rounds, priced,
                admit):
    """Dispatch the resident loop; pull the final state once."""
    n_big = int(lay.tree_n.max())
    out = _device_driver(
        kid, load, send, avail_d, par, cidx, root_d,
        base_edge, anc, valid, tree_id, link_w_slot, cap_slot, res_slot,
        core_base, core_on, core_link_w, alpha_t, ramp_t,
        scal["hot_frac"], scal["w_cap"], scal["cap_beta"], scal["cap_frac"],
        jnp.int32(patience),
        lvl_off=f.lvl_off, lvl_width=f.lvl_width,
        lvl_internal=f.lvl_internal, lvl_sub=f.lvl_sub,
        k=k, cap=bool(opts.cap), use_pallas=bool(use_pallas),
        interpret=bool(opts.interpret), max_rounds=int(max_rounds),
        record=bool(record_rounds), priced=priced, admit=admit,
        n_trees=int(lay.n_trees))
    (best_blue_s, best_round_d, rounds_d, hist_d, prof0_s, prof0c_d,
     best_drop_d, log_rho, log_blue, log_drop) = \
        (np.asarray(x) for x in out)
    bytes_to_host = sum(int(x.nbytes) for x in
                        (best_blue_s, best_round_d, rounds_d, hist_d,
                         prof0_s, prof0c_d, best_drop_d, log_rho, log_blue,
                         log_drop))
    rounds = int(rounds_d)
    best_round = int(best_round_d)
    history = [float(c) for c in hist_d[:rounds]]
    blue_node = _slots_to_nodes_np(best_blue_s, f)
    prof0_node = _slots_to_nodes_np(prof0_s, f, rows=lay.rep)
    rounds_log = None
    if record_rounds:
        rounds_log = []
        for r in range(rounds):
            rho_eff = _slots_to_nodes_np(
                log_rho[r], f).astype(np.float64)[:, :n_big]
            rounds_log.append(
                (rho_eff, _slots_to_nodes_np(log_blue[r], f)[:, :n_big]))
    admission_log = None
    if admit and record_rounds:
        admission_log = [log_drop[r].astype(np.int64) for r in range(rounds)]
    return (blue_node, best_round, rounds, history, prof0_node, prof0c_d,
            rounds_log, bytes_to_host, best_drop_d.astype(np.int64),
            admission_log)


def _run_host(trees, loads, tid_np, avails, f, lay, k, opts, link_w_node,
              cap_node, residual, core_base, core_on, core_link_w, alpha_t,
              ramp_t, scal, patience, max_rounds, record_rounds, priced,
              admit):
    """Host-driven parity reference: one round per step, everything pulled.

    Runs the *same* jitted round arithmetic as the device loop — the
    solve goes through the public :func:`~repro.engine.solve_forest`
    ``rho_scale`` / ``rho_root_add`` overrides (node-indexed weights plus
    the shared-core root extension), measurement and reweight through the
    shared jitted :func:`_round_penalty` — but the loop control, best
    tracking and history live on the host, and each round retains the
    PR 3 driver's serving pattern: re-pack the Forest, re-upload the
    packed arrays, pull the masks, message counts and C_max back down
    (the transfer/packing bill the device loop exists to eliminate; the
    rebuilt arrays are bit-identical, so parity is unaffected).

    With ``admit`` each round replays a literal sequential per-tree
    ledger in tenant order — the admission the device loop's one-hot
    cumsum rank computes in one shot — and persists rejections into
    ``avails`` so the next round's rebuilt Forest excludes them.
    """
    from ..core.congestion import messages_up_forest
    from .batched import solve_forest

    T, n_max = f.mask.shape
    N = int(lay.n_trees)
    C = int(lay.n_core)
    n_big = int(lay.tree_n.max())
    dt = np.dtype(opts.dtype)
    base_edge_node = jnp.asarray(
        np.where(np.isfinite(f.rho_up[:, :, 1]), f.rho_up[:, :, 1], 0.0), dt)
    root_idx = jnp.asarray(f.root)
    tree_id = jnp.asarray(lay.tree_of)
    w = jnp.ones((T, n_max), dt)
    wc = jnp.ones((T, C), dt)
    best = None                     # (cmax, round, blue, drop)
    history: list[float] = []
    rounds_log: list | None = [] if record_rounds else None
    admission_log: list | None = \
        [] if (admit and record_rounds) else None
    prof0_node = prof0_core = None
    bytes_to_host = 0
    stale = 0
    rounds = 0
    for r in range(max_rounds):
        fr = build_forest([trees[g] for g in tid_np], list(loads),
                          avails)                           # PR 3: per round
        if C:
            extra = _core_extra_step(core_base, wc, core_on.astype(dt))
            res = solve_forest(fr, k, options=opts, rho_scale=w,
                               rho_root_add=extra)
        else:
            extra = None
            res = solve_forest(fr, k, options=opts, rho_scale=w)
        blue = res.blue
        bytes_to_host += res.bytes_to_host
        drop = np.zeros(T, np.int64)
        banned = False
        if admit:
            # the sequential ledger the device one-hot cumsum reproduces:
            # claims replayed in tenant order against a fresh per-round
            # copy of the residual; rejections ban the (tenant, switch)
            # pair from every later round via the avail masks
            blue = blue.copy()
            ledger = [rg.copy() for rg in residual]
            for t in range(T):
                g = int(tid_np[t])
                led = ledger[g]
                for v in np.nonzero(blue[t, : trees[g].n])[0]:
                    if led[v] > 0:
                        led[v] -= 1
                    else:
                        blue[t, v] = False
                        avails[t][v] = False
                        drop[t] += 1
                        banned = True
        msgs64 = messages_up_forest(fr, blue)
        msgs = jnp.asarray(msgs64.astype(np.int32))
        bytes_to_host += msgs.nbytes
        prof_tree, prof_core, cmax_d, w2, wc2 = _penalty_step(
            w, wc, msgs, jnp.asarray(blue), root_idx, tree_id, link_w_node,
            core_link_w, core_on, cap_node, alpha_t, ramp_t,
            scal["hot_frac"], scal["w_cap"], scal["cap_beta"],
            scal["cap_frac"], n_trees=N, priced=priced)
        cmax = float(cmax_d)
        bytes_to_host += 4
        history.append(cmax)
        rounds = r + 1
        if r == 0:
            prof0_node = np.asarray(prof_tree)
            prof0_core = np.asarray(prof_core)
            bytes_to_host += prof0_node.nbytes + prof0_core.nbytes
        if record_rounds:
            rho_eff = np.asarray(
                _edge_scale_core(base_edge_node, w, extra, root_idx)
                if C else _edge_scale(base_edge_node, w))
            bytes_to_host += rho_eff.nbytes
            rounds_log.append((rho_eff.astype(np.float64)[:, :n_big],
                               blue[:, :n_big].copy()))
        if admission_log is not None:
            admission_log.append(drop.copy())
        if best is None or cmax < best[0]:           # strict: earliest wins
            best = (cmax, r, blue, drop)
            stale = 0
        else:
            stale += 1
        # a round that banned something changed the search landscape under
        # the loop — it never counts toward the patience stop
        if cmax == 0 or (stale >= patience and not banned):
            break
        w, wc = w2, wc2
    _, best_round, blue_node, best_drop = best
    return (blue_node, best_round, rounds, history, prof0_node, prof0_core,
            rounds_log, bytes_to_host, best_drop, admission_log)
