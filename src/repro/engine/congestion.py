"""Congestion-aware multi-tenant placement: a device-resident penalty loop.

SOAR (and :func:`repro.engine.solve_batch`) minimizes each tenant's *own*
utilization; with T tenants on one shared reduction tree the independently
optimal placements pile messages onto the same links. Following the
congestion objective of Segal et al. 2022 (*Constrained In-network
Computing with Low Congestion in Datacenter Networks*), this driver
minimizes the **max-link congestion**

    C_max = max_e sum_t msg_e^t        (optionally time-weighted by rho_e)

by iterated penalty reweighting of the engine's effective link rates:

  1. solve all T tenants batched against the current per-tenant effective
     rho — the packed rho-up table is rebuilt *on device* from the scaled
     edge rates (:func:`~repro.kernels.minplus.levelfold.rho_up_from_edges`),
     so every round reuses one prebuilt Forest and one compiled gather /
     color executable;
  2. measure per-link traffic from the blue masks with the batched level
     sweep (``repro.core.congestion``) — still on device;
  3. multiplicatively boost each tenant's effective rho on overloaded
     links, proportionally to that tenant's own contribution — the tenants
     responsible for a hotspot are the ones re-routed away from it; a
     deterministic per-tenant penalty gradient (``alpha_t`` ramps with the
     tenant index) breaks ties between look-alike tenants, so identical
     workloads spread instead of migrating in lockstep. With per-switch
     ``capacity`` given, links whose switch is near its capacity claim are
     priced up jointly with hot links (capacity pricing);
  4. re-solve on the reweighted rho and keep the best (strictly lowest
     C_max) placement seen — the loop is monotone-best, never worse than
     the utilization-only baseline (round 0).

**Device-resident loop (default).** ``device_loop=True`` runs the whole
round loop as one jitted ``lax.while_loop``: fused level-fold gather →
on-device color → messages-up sweep → penalty reweight → monotone-best
tracking, with nothing leaving the accelerator between rounds. Only the
best round's masks, the scalar congestion history, and the round-0 profile
transfer at the end (``CongestionResult.bytes_to_host`` reports the
traffic). ``device_loop=False`` keeps the host-driven reference: the same
jitted round pieces called one round at a time through the public
:func:`~repro.engine.solve_forest` ``rho_scale`` API, with masks, counts
and the profile pulled to the host every round (PR 3's transfer pattern).

**Parity.** Both paths run the *identical* float32 update arithmetic —
the shared :func:`_profile` / :func:`_reweight` bodies and the shared
device rho-up recompute — so with ``record_rounds=True`` the two paths
are round-for-round bit-identical: same effective rho, same masks, same
history (asserted in ``tests/test_congestion_device.py``). Weights are
quantized to a dyadic grid (multiples of ``1/1024``), so on dyadic-rho
trees every round's effective rho stays exactly representable in float32
and the batched solve is also bit-identical to the serial
:func:`repro.core.soar.soar` on the same reweighted instance (asserted in
``tests/test_congestion.py``). Utilization and congestion are always
reported against the *original* rho — the penalties shape the search, not
the objective.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.congestion import _messages_body, measure_fleet
from ..core.forest import build_forest
from ..core.tree import Tree
from ..kernels.minplus.levelfold import rho_up_from_edges
from .batched import (_color_body, _device_inputs, _gather_packed,
                      _override_inputs)
from .options import EngineOptions, resolve_options

#: weights are rounded to this dyadic grid so effective rho stays exactly
#: float32-representable on dyadic-rho trees (bit-identical engine/serial)
W_QUANTUM = 1.0 / 1024.0


@dataclasses.dataclass
class CongestionResult:
    """Best placement found by :func:`solve_congestion` plus diagnostics."""

    blue: np.ndarray          # (T, n) bool — best per-tenant masks
    costs: np.ndarray         # (T,) float64 — utilization on the ORIGINAL rho
    msgs: np.ndarray          # (T, n) int64 per-tenant per-link messages
    congestion: np.ndarray    # (n,) per-link congestion of the best round
    max_congestion: float     # C_max of the best round
    mean_congestion: float    # mean over links carrying traffic
    baseline_max: float       # round 0 = utilization-only solve_batch
    baseline_mean: float
    rounds: int               # solve rounds actually run (incl. round 0)
    best_round: int
    history: list             # per-round C_max
    rounds_log: list | None = None   # [(rho_eff (T,n), blue (T,n))] when
                                     # record_rounds=True (parity testing)
    bytes_to_host: int = 0    # device->host traffic the driver actually paid

    @property
    def improvement(self) -> float:
        """Relative max-congestion reduction vs the utilization-only plan."""
        if self.baseline_max <= 0:
            return 0.0
        return 1.0 - self.max_congestion / self.baseline_max


# ---------------------------------------------------------------------------
# shared round arithmetic — the single definition BOTH loop flavors run.
# The device while_loop inlines these; the host reference calls the jitted
# wrappers below. Same traced op sequence -> same float32 results (XLA does
# not contract or reassociate elementwise float ops), which is what makes
# the two paths round-for-round bit-identical. Keep it that way.
# ---------------------------------------------------------------------------

def _profile(msgs: jax.Array, link_w: jax.Array) -> jax.Array:
    """Per-link congestion: int32 counts summed over tenants, then weighted
    (``link_w`` is the original per-link rho when rho_weighted, else 1)."""
    return msgs.sum(axis=0).astype(link_w.dtype) * link_w


def _reweight(w, msgs, prof, cmax, blue, alpha_t, ramp_t, hot_frac, w_cap,
              link_w, capacity, cap_beta, cap_frac, *, priced: bool):
    """One penalty update of the (T, links) weight matrix.

    Hot links (``prof >= hot_frac * cmax``) boost each tenant's weight in
    proportion to that tenant's own traffic share; with ``priced=True``
    links whose switch is crowded (total blue claims near its capacity)
    are priced up jointly, for the tenants sitting on them. One dyadic
    quantization after the joint boost keeps the effective rho exactly
    float32-representable on dyadic trees.
    """
    hot = prof >= hot_frac * cmax
    contrib = msgs.astype(w.dtype) * link_w / cmax
    boost = 1.0 + alpha_t * jnp.where(hot[None, :], contrib, 0.0)
    if priced:
        usage = blue.astype(jnp.int32).sum(axis=0).astype(w.dtype)
        pressure = usage / jnp.maximum(capacity, 1e-6)
        crowded = (pressure >= cap_frac)[None, :] & blue
        boost = boost * (1.0 + cap_beta * ramp_t *
                         jnp.where(crowded, pressure[None, :], 0.0))
    q = jnp.round(w * boost / W_QUANTUM) * W_QUANTUM
    return jnp.minimum(q, w_cap)


_reweight_step = functools.partial(jax.jit, static_argnames=("priced",))(
    _reweight)


@jax.jit
def _profile_step(msgs: jax.Array, link_w: jax.Array):
    """Host-reference measurement: per-link profile plus its max."""
    prof = _profile(msgs, link_w)
    return prof, prof.max()


@jax.jit
def _edge_scale(base_edge: jax.Array, w: jax.Array) -> jax.Array:
    """Effective per-edge rates (the quantity ``record_rounds`` logs)."""
    return base_edge * w


# ---------------------------------------------------------------------------
# the device-resident loop
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("lvl_off", "lvl_width", "lvl_internal", "lvl_sub", "k",
                     "cap", "use_pallas", "interpret", "max_rounds",
                     "record", "priced"))
def _device_driver(
    kid, load, send, avail, par, cidx, root_slot,     # packed solve inputs
    base_edge, anc, valid,                            # rho-override inputs
    link_w, capacity,                                 # (S,) per-link consts
    alpha_t, ramp_t,                                  # (T, 1) tenant ramps
    hot_frac, w_cap, cap_beta, cap_frac, patience,    # scalars
    *,
    lvl_off, lvl_width, lvl_internal, lvl_sub, k, cap, use_pallas,
    interpret, max_rounds: int, record: bool, priced: bool,
):
    """The whole penalty loop as one ``lax.while_loop`` on the accelerator.

    Per round: device rho-up recompute -> fused level-fold gather ->
    on-device color (slot-indexed masks, no node gather) -> messages-up
    sweep -> shared profile/reweight -> monotone-best tracking. The carry
    holds the weight matrix, best-so-far masks, the scalar history and
    (when ``record``) the per-round logs; nothing crosses the host
    boundary until the caller pulls the final tuple.
    """
    T, S, _ = kid.shape
    dt = base_edge.dtype

    def body(carry):
        (r, w, stale, stop, best_cmax, best_blue, best_round,
         history, prof0, log_rho, log_blue) = carry
        edges = base_edge * w
        R = rho_up_from_edges(edges, anc, valid)
        blocks = _gather_packed(
            kid, load, send, avail, R,
            lvl_off=lvl_off, lvl_width=lvl_width,
            lvl_internal=lvl_internal, lvl_sub=lvl_sub,
            k=k, cap=cap, use_pallas=use_pallas, interpret=interpret)
        blue, _ = _color_body(
            blocks, kid, par, cidx, load, send, avail, R, root_slot,
            lvl_off=lvl_off, lvl_width=lvl_width,
            lvl_internal=lvl_internal, lvl_sub=lvl_sub, k=k, cap=cap)
        msgs = _messages_body(
            kid, load, send, blue,
            lvl_off=lvl_off, lvl_width=lvl_width, lvl_internal=lvl_internal)
        prof = _profile(msgs, link_w)
        cmax = prof.max()
        history = history.at[r].set(cmax)
        prof0 = jnp.where(r == 0, prof, prof0)
        if record:
            log_rho = log_rho.at[r].set(edges)
            log_blue = log_blue.at[r].set(blue)
        better = cmax < best_cmax                    # strict: earliest wins
        best_blue = jnp.where(better, blue, best_blue)
        best_round = jnp.where(better, r, best_round)
        best_cmax = jnp.where(better, cmax, best_cmax)
        stale = jnp.where(better, 0, stale + 1)
        stop = (cmax == 0.0) | (stale >= patience)
        w = _reweight(w, msgs, prof, cmax, blue, alpha_t, ramp_t, hot_frac,
                      w_cap, link_w, capacity, cap_beta, cap_frac,
                      priced=priced)
        return (r + 1, w, stale, stop, best_cmax, best_blue, best_round,
                history, prof0, log_rho, log_blue)

    def cond(carry):
        return (carry[0] < max_rounds) & ~carry[3]

    Rl = max_rounds if record else 0
    init = (jnp.int32(0), jnp.ones((T, S), dt), jnp.int32(0),
            jnp.asarray(False), jnp.asarray(jnp.inf, dt),
            jnp.zeros((T, S), bool), jnp.int32(0),
            jnp.full((max_rounds,), -1.0, dt), jnp.zeros((S,), dt),
            jnp.zeros((Rl, T, S), dt), jnp.zeros((Rl, T, S), bool))
    out = jax.lax.while_loop(cond, body, init)
    (r, _, _, _, best_cmax, best_blue, best_round, history, prof0,
     log_rho, log_blue) = out
    return best_blue, best_round, r, history, prof0, log_rho, log_blue


# ---------------------------------------------------------------------------
# the public driver
# ---------------------------------------------------------------------------

def solve_congestion(
    tree: Tree,
    loads: Sequence[np.ndarray],
    k: int,
    avail: Sequence[np.ndarray | None] | np.ndarray | None = None,
    *,
    max_rounds: int = 8,
    patience: int = 2,
    alpha: float = 2.0,
    hot_frac: float = 0.75,
    w_cap: float = 8.0,
    rho_weighted: bool = False,
    capacity: np.ndarray | None = None,
    cap_beta: float = 1.0,
    cap_frac: float = 0.75,
    record_rounds: bool = False,
    device_loop: bool = True,
    options: EngineOptions | None = None,
    **engine_kw,
) -> CongestionResult:
    """Minimize max-link congestion for T tenants sharing ``tree``.

    ``loads``: one (n,) load vector per tenant. ``avail``: a single mask
    shared by all tenants, a per-tenant sequence, or None. ``alpha``
    scales the penalty (each tenant t uses a deterministic ramp
    ``alpha * (1 + t/(T-1))`` — the symmetry breaker for identical
    tenants); links hotter than ``hot_frac * C_max`` are penalized;
    per-link weights are capped at ``w_cap`` and quantized to
    :data:`W_QUANTUM`. ``rho_weighted=True`` measures congestion in
    transmission time (``msg * rho``) instead of raw message counts.

    ``capacity`` (n,) switches on *capacity pricing*: links whose switch
    has blue claims from at least ``cap_frac`` of its per-switch capacity
    this round are priced up (factor ``1 + cap_beta * ramp_t *
    usage/capacity``) jointly with the hot-link boost, for the tenants
    sitting on them — steering the fleet away from switches the
    orchestrator is about to run out of.

    ``device_loop=True`` (default) runs the whole loop on the
    accelerator (one jitted ``lax.while_loop``; O(1) host transfer
    total); ``device_loop=False`` is the host-driven parity reference —
    identical arithmetic, per-round transfers (see module docstring).
    Engine behavior comes from ``options=EngineOptions(...)`` (legacy
    keywords shimmed for one release); ``color=False`` and
    ``debug_tables=True`` are rejected — the driver needs on-device
    masks. Runs at most ``max_rounds`` solves, stopping early after
    ``patience`` rounds without improvement; the returned placement is
    the best round seen, so the result is never worse than the
    utilization-only baseline (round 0).
    """
    T = len(loads)
    if T == 0:
        raise ValueError("solve_congestion needs at least one tenant")
    if max_rounds < 1:
        raise ValueError("max_rounds must be >= 1")
    opts = resolve_options(options, engine_kw, "solve_congestion")
    if not opts.color:
        raise ValueError("solve_congestion needs blue masks; color=False "
                         "(costs-only mode) is not usable here")
    if opts.debug_tables:
        raise ValueError("solve_congestion re-solves on device-side "
                         "effective rho; the debug_tables host replay is "
                         "not usable here")
    n = tree.n
    rho0 = tree.rho
    if avail is None or isinstance(avail, np.ndarray):
        avails = [avail] * T
    else:
        avails = list(avail)
        if len(avails) != T:
            raise ValueError(f"{len(avails)} avail masks for {T} tenants")
    priced = capacity is not None
    if priced:
        capacity = np.asarray(capacity, np.float64)
        if capacity.shape != (n,):
            raise ValueError(f"capacity shape {capacity.shape} != ({n},)")
    use_pallas = opts.use_pallas
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    # one Forest, one packing, one compiled executable for the whole loop
    f = build_forest([tree] * T, list(loads), avails)
    dt = opts.dtype
    kid, load, send, avail_d, _, par, cidx, slot_d, root_d = \
        _device_inputs(f, dt)
    base_edge, anc, valid, _, _ = _override_inputs(f, dt)

    # per-tenant penalty ramp: deterministic symmetry breaker
    ramp_t = jnp.asarray(
        (1.0 + np.arange(T) / max(1, T - 1))[:, None], dt)
    alpha_t = jnp.asarray(alpha, dt) * ramp_t
    scal = dict(hot_frac=jnp.asarray(hot_frac, dt),
                w_cap=jnp.asarray(w_cap, dt),
                cap_beta=jnp.asarray(cap_beta, dt),
                cap_frac=jnp.asarray(cap_frac, dt))
    # node-indexed per-link constants (host reference) and their
    # slot-indexed twins (device loop) — same value per real link, so the
    # two paths' elementwise updates agree bitwise
    link_w_node = np.ones(f.n_max)
    if rho_weighted:
        link_w_node = np.zeros(f.n_max)
        link_w_node[:n] = rho0
    link_w_node = jnp.asarray(link_w_node, dt)
    link_w_slot = base_edge[0] if rho_weighted else jnp.ones(f.n_slots, dt)
    cap_node = np.ones(f.n_max)
    cap_slot = np.ones(f.n_slots)
    if priced:
        cap_node[:n] = capacity
        real0 = f.slot_node[0] >= 0
        cap_slot = np.where(real0, cap_node[np.maximum(f.slot_node[0], 0)],
                            1.0)
    cap_node = jnp.asarray(cap_node, dt)
    cap_slot = jnp.asarray(cap_slot, dt)

    if device_loop:
        state = _run_device(f, k, opts, use_pallas, kid, load, send, avail_d,
                            par, cidx, root_d, base_edge, anc, valid,
                            link_w_slot, cap_slot, alpha_t, ramp_t, scal,
                            patience, max_rounds, record_rounds, priced)
    else:
        state = _run_host(tree, loads, avails, f, k, opts, link_w_node,
                          cap_node, alpha_t, ramp_t, scal, patience,
                          max_rounds, record_rounds, priced)
    (blue_node, best_round, rounds, history, prof0_node, rounds_log,
     bytes_to_host) = state

    blue = blue_node[:, :n]
    # the reported statistics come from the one shared measurement recipe
    # (measure_fleet — same code path the orchestrator's post-admission
    # re-measure uses); its host sweep is bit-identical to the device
    # messages the loop tracked, so nothing shifts in the hand-off
    m = measure_fleet(tree, list(loads), list(blue), rho_weighted)
    base0 = prof0_node[prof0_node > 0]
    return CongestionResult(
        blue=blue, costs=m.costs, msgs=m.msgs, congestion=m.congestion,
        max_congestion=m.max_congestion,
        mean_congestion=m.mean_congestion,
        baseline_max=float(history[0]),
        baseline_mean=float(base0.astype(np.float64).mean())
        if base0.size else 0.0,
        rounds=rounds, best_round=best_round, history=history,
        rounds_log=rounds_log, bytes_to_host=bytes_to_host)


def _slots_to_nodes_np(x_slot: np.ndarray, f) -> np.ndarray:
    """Host twin of the engine's slot->node gather (padding reads 0)."""
    B = x_slot.shape[0]
    pad = np.concatenate(
        [x_slot, np.zeros((B, 1), x_slot.dtype)], axis=1)
    return np.take_along_axis(pad, f.slot_of, axis=1)


def _run_device(f, k, opts, use_pallas, kid, load, send, avail_d, par, cidx,
                root_d, base_edge, anc, valid, link_w_slot, cap_slot,
                alpha_t, ramp_t, scal, patience, max_rounds, record_rounds,
                priced):
    """Dispatch the resident loop; pull the final state once."""
    n = int(f.n[0])
    out = _device_driver(
        kid, load, send, avail_d, par, cidx, root_d,
        base_edge, anc, valid, link_w_slot, cap_slot, alpha_t, ramp_t,
        scal["hot_frac"], scal["w_cap"], scal["cap_beta"], scal["cap_frac"],
        jnp.int32(patience),
        lvl_off=f.lvl_off, lvl_width=f.lvl_width,
        lvl_internal=f.lvl_internal, lvl_sub=f.lvl_sub,
        k=k, cap=bool(opts.cap), use_pallas=bool(use_pallas),
        interpret=bool(opts.interpret), max_rounds=int(max_rounds),
        record=bool(record_rounds), priced=priced)
    best_blue_s, best_round_d, rounds_d, hist_d, prof0_s, log_rho, log_blue \
        = (np.asarray(x) for x in out)
    bytes_to_host = sum(int(x.nbytes) for x in
                        (best_blue_s, best_round_d, rounds_d, hist_d,
                         prof0_s, log_rho, log_blue))
    rounds = int(rounds_d)
    best_round = int(best_round_d)
    history = [float(c) for c in hist_d[:rounds]]
    blue_node = _slots_to_nodes_np(best_blue_s, f)
    prof0_node = _slots_to_nodes_np(prof0_s[None, :], f)[0]
    rounds_log = None
    if record_rounds:
        rounds_log = []
        for r in range(rounds):
            rho_eff = _slots_to_nodes_np(
                log_rho[r], f).astype(np.float64)[:, :n]
            rounds_log.append(
                (rho_eff, _slots_to_nodes_np(log_blue[r], f)[:, :n]))
    return (blue_node, best_round, rounds, history, prof0_node, rounds_log,
            bytes_to_host)


def _run_host(tree, loads, avails, f, k, opts, link_w_node,
              cap_node, alpha_t, ramp_t, scal, patience, max_rounds,
              record_rounds, priced):
    """Host-driven parity reference: one round per step, everything pulled.

    Runs the *same* jitted round arithmetic as the device loop — the
    solve goes through the public :func:`~repro.engine.solve_forest`
    ``rho_scale`` override (node-indexed weights), measurement and
    reweight through the shared jitted steps — but the loop control,
    best tracking and history live on the host, and each round retains
    the PR 3 driver's serving pattern: re-pack the Forest, re-upload the
    packed arrays, pull the masks, message counts and C_max back down
    (the transfer/packing bill the device loop exists to eliminate; the
    rebuilt arrays are bit-identical, so parity is unaffected).
    """
    from ..core.congestion import messages_up_forest
    from .batched import solve_forest

    T, n_max = f.mask.shape
    dt = np.dtype(opts.dtype)
    base_edge_node = jnp.asarray(
        np.where(np.isfinite(f.rho_up[:, :, 1]), f.rho_up[:, :, 1], 0.0), dt)
    w = jnp.ones((T, n_max), dt)
    best = None                     # (cmax, round, blue)
    history: list[float] = []
    rounds_log: list | None = [] if record_rounds else None
    prof0_node = None
    bytes_to_host = 0
    stale = 0
    rounds = 0
    for r in range(max_rounds):
        fr = build_forest([tree] * T, list(loads), avails)  # PR 3: per round
        res = solve_forest(fr, k, options=opts, rho_scale=w)
        blue = res.blue
        bytes_to_host += res.bytes_to_host
        msgs64 = messages_up_forest(fr, blue)
        msgs = jnp.asarray(msgs64.astype(np.int32))
        bytes_to_host += msgs.nbytes
        prof_d, cmax_d = _profile_step(msgs, link_w_node)
        cmax = float(cmax_d)
        bytes_to_host += 4
        history.append(cmax)
        rounds = r + 1
        if r == 0:
            prof0_node = np.asarray(prof_d)
            bytes_to_host += prof0_node.nbytes
        if record_rounds:
            rho_eff = np.asarray(_edge_scale(base_edge_node, w))
            bytes_to_host += rho_eff.nbytes
            rounds_log.append((rho_eff.astype(np.float64)[:, : int(f.n[0])],
                               blue[:, : int(f.n[0])].copy()))
        if best is None or cmax < best[0]:           # strict: earliest wins
            best = (cmax, r, blue)
            stale = 0
        else:
            stale += 1
        if cmax == 0 or stale >= patience:
            break
        w = _reweight_step(w, msgs, prof_d, cmax_d, jnp.asarray(blue),
                           alpha_t, ramp_t, scal["hot_frac"], scal["w_cap"],
                           link_w_node, cap_node, scal["cap_beta"],
                           scal["cap_frac"], priced=priced)
    _, best_round, blue_node = best
    return (blue_node, best_round, rounds, history, prof0_node, rounds_log,
            bytes_to_host)
