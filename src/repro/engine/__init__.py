"""Batched multi-tenant SOAR placement engine.

``solve_batch(trees, loads, k, avail)`` solves B phi-BIC instances in one
device-resident level-synchronous JAX sweep — fused level-fold gather plus
on-device traceback; only masks and costs leave the accelerator (see
``batched.py``). ``solve_congestion`` iterates that solve under penalty-
reweighted link rates to minimize *max-link congestion* across tenants
sharing one tree — by default the whole round loop runs on device as one
jitted ``lax.while_loop`` (see ``congestion.py``). Engine behavior is
configured through the frozen :class:`EngineOptions` dataclass (see
``options.py``); the serial per-instance solvers stay in ``repro.core``.

``solve_fleet`` generalizes the congestion loop to N aggregation trees
hanging off a shared core: per-round profiling and penalty reweighting run
over the union of tree-local and shared-core links inside the same jitted
while-loop, and ``solve_congestion`` is its degenerate single-tree call.
"""
from .batched import (BatchResult, cache_stats, color_batch, gather_batch,
                      solve_batch, solve_forest)
from .congestion import CongestionResult, solve_congestion, solve_fleet
from .options import EngineOptions

__all__ = ["BatchResult", "CongestionResult", "EngineOptions", "cache_stats",
           "color_batch", "gather_batch", "solve_batch", "solve_congestion",
           "solve_fleet", "solve_forest"]
