"""Batched multi-tenant SOAR placement engine.

``solve_batch(trees, loads, k, avail)`` solves B phi-BIC instances in one
device-resident level-synchronous JAX sweep — fused level-fold gather plus
on-device traceback; only masks and costs leave the accelerator (see
``batched.py``). ``solve_congestion`` iterates that solve under penalty-
reweighted link rates to minimize *max-link congestion* across tenants
sharing one tree — by default the whole round loop runs on device as one
jitted ``lax.while_loop`` (see ``congestion.py``). Engine behavior is
configured through the frozen :class:`EngineOptions` dataclass (see
``options.py``); the serial per-instance solvers stay in ``repro.core``.
"""
from .batched import (BatchResult, cache_stats, color_batch, gather_batch,
                      solve_batch, solve_forest)
from .congestion import CongestionResult, solve_congestion
from .options import EngineOptions

__all__ = ["BatchResult", "CongestionResult", "EngineOptions", "cache_stats",
           "color_batch", "gather_batch", "solve_batch", "solve_congestion",
           "solve_forest"]
