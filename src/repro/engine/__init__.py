"""Batched multi-tenant SOAR placement engine.

``solve_batch(trees, loads, k, avail)`` solves B phi-BIC instances in one
level-synchronous JAX sweep (see ``batched.py``); the serial per-instance
solvers stay in ``repro.core``.
"""
from .batched import (BatchResult, color_batch, gather_batch, solve_batch,
                      solve_forest)

__all__ = ["BatchResult", "color_batch", "gather_batch", "solve_batch",
           "solve_forest"]
