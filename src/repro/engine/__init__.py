"""Batched multi-tenant SOAR placement engine.

``solve_batch(trees, loads, k, avail)`` solves B phi-BIC instances in one
device-resident level-synchronous JAX sweep — fused level-fold gather plus
on-device traceback; only masks and costs leave the accelerator (see
``batched.py``). ``solve_congestion`` iterates that solve under penalty-
reweighted link rates to minimize *max-link congestion* across tenants
sharing one tree (see ``congestion.py``). The serial per-instance solvers
stay in ``repro.core``.
"""
from .batched import (BatchResult, cache_stats, color_batch, gather_batch,
                      solve_batch, solve_forest)
from .congestion import CongestionResult, solve_congestion

__all__ = ["BatchResult", "CongestionResult", "cache_stats", "color_batch",
           "gather_batch", "solve_batch", "solve_congestion", "solve_forest"]
