"""Batched multi-tenant SOAR placement engine (JAX).

Solves B phi-BIC instances at once over the level-packed
:class:`repro.core.forest.Forest` layout:

  * **Gather** — a level-synchronous sweep (deepest level first) where all
    nodes of a depth level, across *all* instances, are processed
    together. The budget-split min over children (the mCost tropical
    convolution of Algorithm 3) becomes one batched min-plus over every
    (instance, node, ell) row of the level's *internal* sub-block,
    dispatched to the Pallas TPU kernel in ``repro.kernels.minplus`` on
    TPU and to a fused jnp shift-reduction elsewhere. Leaves are pure
    elementwise. Because each level is a contiguous slot block, results
    land via static slice updates — no scatter ops.
  * **Color** — the traceback is orders of magnitude cheaper than the
    gather (paper Sec. 5.4 / fig9) and runs on the host, but also level
    synchronously: all nodes of a level, across all instances, replay
    their budget split with vectorized numpy (see :func:`color_batch`).

Numerics: the DP runs on a finite ``BIG`` sentinel instead of ``inf`` so
that ``0 * BIG`` stays finite (padded slots would otherwise produce NaN
via ``0 * inf``). Tables are float32 by default; instances whose rho
values are exactly representable (dyadic rates — every paper topology and
the fleet trees) reproduce the float64 reference *bit-exactly*; arbitrary
rates match to float32 eps. Pass ``dtype=jnp.float64`` under
``jax_enable_x64`` for exactness on arbitrary rates.

The min-plus identity here is the all-zeros vector, not ``[0, inf, ...]``:
DP tables are monotone non-increasing in the budget (at-most-k), and for
monotone A, ``minplus(A, 0)[i] = min_{j<=i} A[i-j] = A[i]`` — so missing
children (the identity slot) fold as no-ops while leaf and padded slots
stay finite.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.forest import Forest, build_forest
from ..core.tree import Tree
from ..core.tropical import minplus_batch

BIG = 1e18  # finite +inf stand-in; exactly representable in float32


def _minplus_fused(a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused jnp min-plus convolution, (rows, K) x (rows, K) -> (rows, K).

    The j-shift reduction of the Pallas kernel body, unrolled over the
    (static) budget width so XLA fuses it into one elementwise loop — no
    (rows, K, K) candidate tensor is ever materialized.
    """
    rows, k = a.shape
    acc = a + b[:, :1]
    for j in range(1, k):
        shifted = jnp.concatenate(
            [jnp.full((rows, j), BIG, a.dtype), a[:, : k - j]], axis=1)
        acc = jnp.minimum(acc, shifted + b[:, j : j + 1])
    return acc


def _minplus_rows(a: jax.Array, b: jax.Array, use_pallas: bool,
                  interpret: bool) -> jax.Array:
    """Backend dispatch for the batched tropical convolution."""
    if use_pallas:
        from ..kernels.minplus.ops import minplus
        return minplus(a, b, interpret=interpret)
    return _minplus_fused(a, b)


@functools.partial(
    jax.jit,
    static_argnames=("lvl_off", "lvl_width", "lvl_internal", "k",
                     "use_pallas", "interpret"))
def _gather_packed(
    pk_kid: jax.Array,     # (B, S, max_c) int32 child slots, sentinel S
    pk_load: jax.Array,    # (B, S)
    pk_send: jax.Array,    # (B, S)
    pk_avail: jax.Array,   # (B, S) bool
    pk_rho_up: jax.Array,  # (B, S, h_max+2), BIG at invalid ell
    *,
    lvl_off: tuple,
    lvl_width: tuple,
    lvl_internal: tuple,
    k: int,
    use_pallas: bool,
    interpret: bool,
) -> jax.Array:
    """Level-synchronous batched SOAR-Gather over the packed slot layout.

    Returns DP tables ``X[b, s, ell, i]`` of shape ``(B, S+1, h_max+2,
    k+1)``; slot ``S`` is the all-zeros min-plus identity, rows beyond a
    node's ``depth+1`` stay BIG, padded slots hold finite garbage that is
    never read back.
    """
    B, S, max_c = pk_kid.shape
    H2 = pk_rho_up.shape[2]
    h_max = H2 - 2
    K = k + 1
    dt = pk_rho_up.dtype
    loadf = pk_load.astype(dt)
    sendf = pk_send.astype(dt)

    X = jnp.full((B, S + 1, H2, K), BIG, dt)
    X = X.at[:, S].set(0.0)                            # identity slot

    for d in range(h_max, -1, -1):
        o, W, Wi = lvl_off[d], lvl_width[d], lvl_internal[d]
        nl = d + 2                                     # valid rows 0..d+1
        rl = pk_rho_up[:, o : o + W, :nl, None]        # (B, W, nl, 1)
        if Wi > 0:
            # red chain: children see the barrier one hop further -> child
            # row ell+1 aligns with row ell. Internal nodes only exist at
            # d < h_max, so rows 1..nl+1 always fit in H2.
            kidv = pk_kid[:, o : o + Wi]               # (B, Wi, max_c)
            Xs = X[:, :, 1 : nl + 1, :]
            c0 = kidv[:, :, 0]
            acc_r = jnp.take_along_axis(Xs, c0[:, :, None, None], axis=1)
            acc_b = jnp.take_along_axis(X[:, :, 1, :], c0[:, :, None], axis=1)
            for m in range(1, max_c):
                cm = kidv[:, :, m]
                ch_r = jnp.take_along_axis(Xs, cm[:, :, None, None], axis=1)
                ch_b = jnp.take_along_axis(X[:, :, 1, :], cm[:, :, None],
                                           axis=1)
                # one fused convolution over all (b, v, ell) + blue rows
                a = jnp.concatenate([acc_r.reshape(-1, K),
                                     acc_b.reshape(-1, K)])
                b = jnp.concatenate([ch_r.reshape(-1, K),
                                     ch_b.reshape(-1, K)])
                y = _minplus_rows(a, b, use_pallas, interpret)
                acc_r = y[: B * Wi * nl].reshape(B, Wi, nl, K)
                acc_b = y[B * Wi * nl :].reshape(B, Wi, K)
            rli = rl[:, :Wi]
            red = acc_r + loadf[:, o : o + Wi, None, None] * rli
            # blue: budget shifts by one (v spends a slot on itself)
            blue = jnp.concatenate(
                [jnp.full((B, Wi, nl, 1), BIG, dt),
                 acc_b[:, :, None, :-1]
                 + sendf[:, o : o + Wi, None, None] * rli], axis=-1)
            blue = jnp.where(pk_avail[:, o : o + Wi, None, None], blue, BIG)
            out = jnp.minimum(red, blue)
            out = jax.lax.cummin(out, axis=3)          # at-most-k monotone
            X = X.at[:, o : o + Wi, :nl, :].set(out)
        if W - Wi > 0:
            # leaves: X_v(l, 0) = L(v) rho; X_v(l, i>=1) also allows blue
            lo = o + Wi
            rll = rl[:, Wi:]
            lr = loadf[:, lo : o + W, None, None] * rll    # (B, Wl, nl, 1)
            sr = sendf[:, lo : o + W, None, None] * rll
            rest = jnp.where(pk_avail[:, lo : o + W, None, None],
                             jnp.minimum(lr, sr), lr)
            out = jnp.concatenate(
                [lr, jnp.broadcast_to(rest, (*rest.shape[:3], K - 1))],
                axis=-1)
            X = X.at[:, lo : o + W, :nl, :].set(out)
    return X


def _gather_device(f: Forest, k: int, dtype, use_pallas: bool,
                   interpret: bool) -> jax.Array:
    R = np.where(np.isfinite(f.pk_rho_up), f.pk_rho_up, BIG)
    return _gather_packed(
        jnp.asarray(f.pk_kid), jnp.asarray(f.pk_load),
        jnp.asarray(f.pk_send), jnp.asarray(f.pk_avail),
        jnp.asarray(R, dtype),
        lvl_off=f.lvl_off, lvl_width=f.lvl_width,
        lvl_internal=f.lvl_internal,
        k=k, use_pallas=bool(use_pallas), interpret=bool(interpret))


def _unpack_tables(f: Forest, X: jax.Array) -> np.ndarray:
    """Slot-indexed device tables -> node-indexed host float64 tables."""
    Xh = np.asarray(X, np.float64)                     # (B, S+1, H2, K)
    # node v of instance b lives at slot slot_of[b, v]; padded nodes point
    # at the identity slot, which is exactly the zero table color_batch
    # expects at index n_max.
    idx = np.concatenate(
        [f.slot_of, np.full((f.batch, 1), f.n_slots, np.int32)], axis=1)
    return Xh[np.arange(f.batch)[:, None], idx]


def gather_batch(f: Forest, k: int, *, dtype=jnp.float32,
                 use_pallas: bool = False,
                 interpret: bool = False) -> np.ndarray:
    """Batched SOAR-Gather; returns *node-indexed* DP tables.

    Shape ``(B, n_max+1, h_max+2, k+1)`` float64 on host; index ``n_max``
    is the all-zeros identity slot (what sentinel children point at).
    """
    return _unpack_tables(
        f, _gather_device(f, k, dtype, use_pallas, interpret))


def color_batch(f: Forest, X: np.ndarray, k: int) -> np.ndarray:
    """Batched SOAR-Color: level-synchronous traceback over all instances.

    ``X`` are the node-indexed gathered tables (host, float64). Replays
    Algorithm 4's budget split with the exact tie-breaking of the serial
    ``soar_color`` (blue iff strictly better; first minimizer of each
    child split), vectorized over every node of a level across the batch.
    """
    B, n_max = f.mask.shape
    K = k + 1
    R = np.where(np.isfinite(f.rho_up), f.rho_up, BIG)
    blue = np.zeros((B, n_max), bool)
    budget_at = np.zeros((B, n_max), np.int64)   # budget i for T_v
    ell_at = np.ones((B, n_max), np.int64)       # dist to closest blue anc/d
    budget_at[np.arange(B), f.root] = k
    jj = np.arange(K)[None, :]

    for d, nd in enumerate(f.levels):
        valid = nd < n_max                           # real nodes only
        bv, wv = np.nonzero(valid)
        if len(bv) == 0:
            continue
        vv = nd[bv, wv]
        rows = len(vv)
        ar = np.arange(rows)
        i = budget_at[bv, vv]
        ell = ell_at[bv, vv]
        rl = R[bv, vv, ell]
        kids = f.kid[bv, vv]                         # (rows, max_c)
        # partial min-plus chains over children, red (row ell+1) and blue
        # (row 1) variants; sentinel children hit the zero identity slot.
        # Clip the red row: it only saturates for deepest-level leaves,
        # whose children are all sentinel (zero at every row).
        er = np.minimum(ell + 1, X.shape[2] - 1)
        ch_r = np.empty((rows, f.max_children, K))
        ch_b = np.empty((rows, f.max_children, K))
        ch_r[:, 0] = X[bv, kids[:, 0], er]
        ch_b[:, 0] = X[bv, kids[:, 0], 1]
        for m in range(1, f.max_children):
            ch_r[:, m] = minplus_batch(ch_r[:, m - 1], X[bv, kids[:, m], er])
            ch_b[:, m] = minplus_batch(ch_b[:, m - 1], X[bv, kids[:, m], 1])
        red_val = ch_r[ar, -1, i] + f.load[bv, vv] * rl
        can_blue = f.avail[bv, vv] & (i >= 1)
        blue_val = np.where(
            can_blue,
            ch_b[ar, -1, np.clip(i - 1, 0, K - 1)] + f.send[bv, vv] * rl,
            np.inf)
        isblue = blue_val < red_val                  # strict, as in serial
        blue[bv, vv] = isblue
        budget = i - isblue.astype(np.int64)
        lc = np.where(isblue, 1, ell + 1)
        lcc = np.minimum(lc, X.shape[2] - 1)         # saturates only for
        chain = np.where(isblue[:, None, None], ch_b, ch_r)  # sentinel reads
        # split the budget among children, last child first (mSplit replay)
        for m in range(f.max_children - 1, 0, -1):
            c = kids[:, m]
            real = c < n_max
            Xc = X[bv, c, lcc]                       # (rows, K)
            prev = chain[:, m - 1]
            feas = jj <= budget[:, None]
            vals = prev[ar[:, None], np.clip(budget[:, None] - jj, 0, K - 1)]
            vals = np.where(feas, vals + Xc, np.inf)
            best_j = np.argmin(vals, axis=1)         # first minimizer
            budget_at[bv[real], c[real]] = best_j[real]
            ell_at[bv[real], c[real]] = lc[real]
            budget = budget - np.where(real, best_j, 0)
        c = kids[:, 0]
        real = c < n_max
        budget_at[bv[real], c[real]] = budget[real]
        ell_at[bv[real], c[real]] = lc[real]
    return blue


@dataclasses.dataclass
class BatchResult:
    """Output of :func:`solve_batch` for B padded instances."""

    blue: np.ndarray | None   # (B, n_max) bool, False at padding; None
                              # in costs-only mode (color=False)
    costs: np.ndarray         # (B,) float64 — optimal phi per instance
    n: np.ndarray             # (B,) real node counts (mask key for blue)

    def blue_of(self, b: int) -> np.ndarray:
        """Unpadded blue mask of instance b."""
        if self.blue is None:
            raise ValueError("solve_batch ran with color=False")
        return self.blue[b, : int(self.n[b])]


def solve_forest(
    f: Forest,
    k: int,
    *,
    color: bool = True,
    dtype=jnp.float32,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> BatchResult:
    """:func:`solve_batch` for a pre-built Forest (amortizes packing)."""
    if k < 0:
        raise ValueError("budget k must be non-negative")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    X = _gather_device(f, k, dtype, use_pallas, interpret)
    root_slot = f.slot_of[np.arange(f.batch), f.root]
    if not color:
        # costs-only planning mode: pull back B scalars, not the tables
        roots = X[jnp.arange(f.batch), jnp.asarray(root_slot), 1, k]
        return BatchResult(blue=None,
                           costs=np.asarray(roots, np.float64),
                           n=f.n.copy())
    Xn = _unpack_tables(f, X)
    costs = Xn[np.arange(f.batch), f.root, 1, k]
    return BatchResult(blue=color_batch(f, Xn, k), costs=costs,
                       n=f.n.copy())


def solve_batch(
    trees: Sequence[Tree],
    loads: Sequence[np.ndarray],
    k: int,
    avail: Sequence[np.ndarray] | None = None,
    **kw,
) -> BatchResult:
    """Solve B phi-BIC instances at once; per-instance output contract of
    :func:`repro.core.soar.soar` (optimal costs, at-most-k blue masks).

    Instances may be ragged (different n, height, children); batches of
    similar shape share one compiled executable (jit key: the packed
    level layout + ``k``). ``use_pallas=None`` auto-dispatches: Pallas
    kernel on TPU, fused jnp elsewhere.
    """
    return solve_forest(build_forest(trees, loads, avail), k, **kw)
