"""Batched multi-tenant SOAR placement engine (JAX), device-resident.

Solves B phi-BIC instances at once over the level-packed
:class:`repro.core.forest.Forest` layout. Both halves of SOAR now run on
the accelerator, and only the answers cross the host/device boundary:

  * **Gather** — a level-synchronous sweep (deepest level first) where all
    nodes of a depth level, across *all* instances, are processed
    together. The budget-split min over children (the mCost tropical
    convolution of Algorithm 3) runs through the **fused level-fold**
    in ``repro.kernels.minplus.levelfold``: one launch per level that
    gathers every child's rows and chains the convolutions in-register
    (Pallas kernel on TPU, fused jnp elsewhere). Convolution widths are
    truncated per level to the ``min(k, subtree size)`` knapsack bound
    (``Forest.lvl_sub``) and flat-padded back — exact for the monotone
    at-most-k tables, and most of a tree's nodes sit in deep levels with
    tiny subtrees. Because each level is a contiguous slot block, results
    land via static slice updates — no scatter ops.
  * **Color** — the traceback also runs on device: a top-down
    level-synchronous sweep over the same packed layout replays each
    node's budget split against the resident DP tables with the serial
    solver's exact tie-breaking (blue iff strictly better; first
    minimizer per child split). The sweep is scatter-free: each level
    publishes its split matrix and the next level *gathers* its budget
    and barrier distance through inverse parent pointers. No
    backpointers are stored — splits are re-derived from the tables,
    which are already in device memory.

Only the ``(B, n_max)`` blue masks and ``(B,)`` costs are pulled back to
the host (``BatchResult.bytes_to_host`` reports the traffic); the full
``(B, S+1, h_max+2, k+1)`` table pullback plus host-numpy
:func:`color_batch` replay of PR 1 survives behind the
``debug_tables=True`` escape hatch.

Numerics: the DP runs on the finite ``BIG`` sentinel
(``repro.core.tropical.BIG``) instead of ``inf`` so that ``0 * BIG``
stays finite. Tables are float32 by default; instances whose rho values
are exactly representable (dyadic rates — every paper topology and the
fleet trees) reproduce the float64 reference *bit-exactly*; arbitrary
rates match to float32 eps. Pass ``dtype=jnp.float64`` under
``jax_enable_x64`` for exactness on arbitrary rates.

The min-plus identity here is the all-zeros vector, not ``[0, inf, ...]``:
DP tables are monotone non-increasing in the budget (at-most-k), and for
monotone A, ``minplus(A, 0)[i] = min_{j<=i} A[i-j] = A[i]`` — so missing
children (the identity slot) fold as no-ops while leaf and padded slots
stay finite.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.forest import Forest, build_forest, layout_stats
from ..core.tree import Tree
from ..core.tropical import BIG, minplus_batch
from ..kernels.minplus.levelfold import (chain_fold, level_fold,
                                         minplus_fused, rho_up_from_edges,
                                         scaled_edges)
from .options import EngineOptions, resolve_options

# back-compat alias: the engine's fused convolution now lives with the
# level-fold kernel so both backends share one bit-exact implementation
_minplus_fused = minplus_fused


@functools.partial(
    jax.jit,
    static_argnames=("lvl_off", "lvl_width", "lvl_internal", "lvl_sub", "k",
                     "cap", "use_pallas", "interpret"))
def _gather_packed(
    pk_kid: jax.Array,     # (B, S, max_c) int32 child slots, sentinel S
    pk_load: jax.Array,    # (B, S)
    pk_send: jax.Array,    # (B, S)
    pk_avail: jax.Array,   # (B, S) bool
    pk_rho_up: jax.Array,  # (B, S, h_max+2), BIG at invalid ell
    *,
    lvl_off: tuple,
    lvl_width: tuple,
    lvl_internal: tuple,
    lvl_sub: tuple,
    k: int,
    cap: bool,
    use_pallas: bool,
    interpret: bool,
) -> tuple:
    """Level-synchronous batched SOAR-Gather over the packed slot layout.

    Returns the DP tables as a tuple of per-level **blocks**
    ``blocks[d]`` of shape ``(B, W_d, d+2, k+1)`` (level d's slots, their
    valid barrier rows 0..d+1) rather than one monolithic slot array: a
    node's children live exactly one level down, so each fold only ever
    reads the adjacent block — and the sweep never pays a functional
    whole-table update per level. Padded slots hold finite garbage that
    is never read back. With ``cap=True`` each level's fold runs at the
    truncated width ``min(k, lvl_sub[d]) + 1`` and is flat-padded to k+1
    (exact: monotone tables are constant beyond their subtree's budget).
    """
    B, S, max_c = pk_kid.shape
    H2 = pk_rho_up.shape[2]
    h_max = H2 - 2
    K = k + 1
    dt = pk_rho_up.dtype
    loadf = pk_load.astype(dt)
    sendf = pk_send.astype(dt)

    blocks: list = [None] * (h_max + 1)
    for d in range(h_max, -1, -1):
        o, W, Wi = lvl_off[d], lvl_width[d], lvl_internal[d]
        nl = d + 2                                     # valid rows 0..d+1
        if W == 0:                                     # bucketed tail level
            blocks[d] = jnp.zeros((B, 0, nl, K), dt)
            continue
        Kd = min(K, lvl_sub[d] + 1) if cap else K
        rl = pk_rho_up[:, o : o + W, :nl, None]        # (B, W, nl, 1)
        parts = []
        if Wi > 0:
            # red chain: children see the barrier one hop further -> child
            # rows 1..nl+1 align with our rows 0..nl (they fit: the child
            # block has nl+1 rows). Children are addressed level-locally,
            # with the all-zeros min-plus identity appended at index W1.
            o1, W1 = lvl_off[d + 1], lvl_width[d + 1]
            ch = blocks[d + 1]
            xs = jnp.concatenate(
                [ch[:, :, 1 : nl + 1, :Kd],
                 jnp.zeros((B, 1, nl, Kd), dt)], axis=1)
            xb = jnp.concatenate(
                [ch[:, :, 1, :Kd], jnp.zeros((B, 1, Kd), dt)], axis=1)
            kid_local = jnp.minimum(pk_kid[:, o : o + Wi] - o1, W1)
            out = level_fold(
                xs, xb, kid_local, loadf[:, o : o + Wi],
                sendf[:, o : o + Wi], pk_avail[:, o : o + Wi],
                pk_rho_up[:, o : o + Wi, :nl], nl=nl, kcap=Kd,
                use_pallas=use_pallas, interpret=interpret)
            if Kd < K:                                 # flat-pad (monotone)
                out = jnp.concatenate(
                    [out, jnp.broadcast_to(out[..., -1:],
                                           (B, Wi, nl, K - Kd))], axis=-1)
            parts.append(out)
        if W - Wi > 0:
            # leaves: X_v(l, 0) = L(v) rho; X_v(l, i>=1) also allows blue
            lo = o + Wi
            rll = rl[:, Wi:]
            lr = loadf[:, lo : o + W, None, None] * rll    # (B, Wl, nl, 1)
            sr = sendf[:, lo : o + W, None, None] * rll
            rest = jnp.where(pk_avail[:, lo : o + W, None, None],
                             jnp.minimum(lr, sr), lr)
            parts.append(jnp.concatenate(
                [lr, jnp.broadcast_to(rest, (*rest.shape[:3], K - 1))],
                axis=-1))
        blocks[d] = parts[0] if len(parts) == 1 else jnp.concatenate(
            parts, axis=1)
    return tuple(blocks)


def _color_body(
    blocks: tuple,         # per-level gather blocks, see _gather_packed
    pk_kid: jax.Array,     # (B, S, max_c) int32 child slots, sentinel S
    pk_par: jax.Array,     # (B, S) int32 parent's index in *its* level block
    pk_cidx: jax.Array,    # (B, S) int32 own index in parent's child list
    pk_load: jax.Array,    # (B, S)
    pk_send: jax.Array,    # (B, S)
    pk_avail: jax.Array,   # (B, S) bool
    pk_rho_up: jax.Array,  # (B, S, H2), BIG at invalid ell
    root_slot: jax.Array,  # (B,) int32
    *,
    lvl_off: tuple,
    lvl_width: tuple,
    lvl_internal: tuple,
    lvl_sub: tuple,
    k: int,
    cap: bool,
) -> tuple[jax.Array, jax.Array]:
    """On-device SOAR-Color: top-down level-synchronous traceback.

    Plain traceable function (jitted callers: :func:`_color_packed` for the
    node-indexed public result, the device-resident congestion loop for the
    slot-indexed masks its message sweep consumes directly). Returns the
    ``(B, n_slots)`` *slot-indexed* blue mask plus the ``(B,)`` costs.

    Replays Algorithm 4's budget split against the resident per-level
    table blocks with the exact tie-breaking of the serial ``soar_color``
    (blue iff *strictly* better; *first* minimizer of each child split —
    both ``jnp.argmin`` semantics). The sweep is **scatter-free**:
    instead of parents scattering budgets down to child slots, each level
    stores its internal nodes' split matrix and the next level *gathers*
    its budget and barrier distance through the inverse pointers
    ``pk_par`` / ``pk_cidx`` (XLA:CPU compiles gathers orders of
    magnitude faster than the equivalent scatter chain). Like the gather,
    the replayed chains run at the level's ``min(k, lvl_sub[d]) + 1``
    truncated width: a level-d node can never hold more budget than its
    subtree (the root may, when k > n — all its reads then land in the
    flat region of the monotone tables, where clipped indexing is exact,
    and the first-minimizer split provably stays below the cap). Leaves
    (the back of each level block) skip chains and splits entirely —
    their blue test is elementwise.
    """
    B, _, max_c = pk_kid.shape
    K = k + 1
    dt = blocks[0].dtype
    loadf = pk_load.astype(dt)
    sendf = pk_send.astype(dt)

    blue_parts = []
    prev_split = prev_lc = None      # prev level's child budgets / barrier
    for d, (o, W, Wi) in enumerate(zip(lvl_off, lvl_width, lvl_internal)):
        if W == 0:
            continue                 # bucketed heights: only trailing levels
        if d == 0:
            ids = o + jnp.arange(W, dtype=jnp.int32)[None, :]
            i = jnp.where(ids == root_slot[:, None], k, 0).astype(jnp.int32)
            el = jnp.ones((B, W), jnp.int32)
        else:
            pl = pk_par[:, o : o + W]
            i = jnp.take_along_axis(
                prev_split, pl * max_c + pk_cidx[:, o : o + W], axis=1)
            el = jnp.take_along_axis(prev_lc, pl, axis=1)
        rl = jnp.take_along_axis(pk_rho_up[:, o : o + W], el[:, :, None],
                                 axis=2)[..., 0]
        can_blue = pk_avail[:, o : o + W] & (i >= 1)
        if Wi < W:
            # leaves: no children to chain or split — elementwise test
            red_l = loadf[:, o + Wi : o + W] * rl[:, Wi:]
            blue_l = jnp.where(can_blue[:, Wi:],
                               sendf[:, o + Wi : o + W] * rl[:, Wi:],
                               jnp.inf)
            leaf_blue = blue_l < red_l
        if Wi == 0:
            blue_parts.append(leaf_blue)
            continue                 # leaf-only level: nothing deeper
        Kc = min(K, lvl_sub[d] + 1) if cap else K
        jj = jnp.arange(Kc)[None, None, :]
        i_in, el_in = i[:, :Wi], el[:, :Wi]
        o1, W1 = lvl_off[d + 1], lvl_width[d + 1]
        nl1 = d + 3                  # rows of the child level's block
        ch = jnp.concatenate(
            [blocks[d + 1][..., :Kc],
             jnp.zeros((B, 1, nl1, Kc), dt)], axis=1)  # + identity
        chf = ch.reshape(B, (W1 + 1) * nl1, Kc)
        kidl = jnp.minimum(pk_kid[:, o : o + Wi] - o1, W1)

        def slot_rows(row, kidl=kidl, chf=chf, nl1=nl1, Kc=Kc):
            """All children's tables at per-node row: (B, Wi, max_c, Kc)."""
            idx = (kidl * nl1 + row[:, :, None]).reshape(B, Wi * max_c)
            return jnp.take_along_axis(
                chf, idx[:, :, None], axis=1).reshape(B, Wi, max_c, Kc)

        # partial min-plus chains over children, red (row ell+1) and blue
        # (row 1) variants; sentinel children hit the appended identity.
        # chain_fold is the same fold the gather ran, so replayed values
        # match the tables bit-for-bit.
        er = el_in + 1               # <= d+2: always inside the child block
        row1 = jnp.ones_like(er)
        st_r = jnp.moveaxis(slot_rows(er), 2, 0).reshape(max_c, B * Wi, Kc)
        st_b = jnp.moveaxis(slot_rows(row1), 2, 0).reshape(max_c, B * Wi, Kc)
        st = jnp.concatenate([st_r, st_b], axis=1)     # (max_c, 2BWi, Kc)
        _, parts = chain_fold(st, collect=True)
        ch_r = parts[:, : B * Wi].reshape(max_c, B, Wi, Kc)
        ch_b = parts[:, B * Wi :].reshape(max_c, B, Wi, Kc)
        ic = jnp.minimum(i_in, Kc - 1)                 # flat-region clip
        red_val = jnp.take_along_axis(ch_r[-1], ic[..., None],
                                      axis=2)[..., 0] + loadf[:, o : o + Wi] * rl[:, :Wi]
        ib = jnp.clip(i_in - 1, 0, Kc - 1)
        blue_val = jnp.where(
            can_blue[:, :Wi],
            jnp.take_along_axis(ch_b[-1], ib[..., None], axis=2)[..., 0]
            + sendf[:, o : o + Wi] * rl[:, :Wi],
            jnp.inf)
        isblue = blue_val < red_val                    # strict, as in serial
        blue_parts.append(isblue if Wi == W else
                          jnp.concatenate([isblue, leaf_blue], axis=1))
        bud = i_in - isblue.astype(jnp.int32)
        lc = jnp.where(isblue, 1, el_in + 1)
        # split the budget among children, last child first (mSplit
        # replay), again as a scan over the child index. Sentinel children
        # read the identity's zero table: their vals are the (monotone
        # non-increasing) partial chain at bud - j, which is non-decreasing
        # in j, so the first minimizer is j = 0 and the running budget
        # passes through untouched — no masking needed.
        chain = jnp.where(isblue[None, :, :, None], ch_b, ch_r)
        # children see the barrier at row lc = isblue ? 1 : ell+1 — both
        # variants were already gathered (st_b at row 1, st_r at ell+1),
        # so select instead of gathering a third time
        xc = jnp.where(isblue[None, :, :, None],
                       st_b.reshape(max_c, B, Wi, Kc),
                       st_r.reshape(max_c, B, Wi, Kc))
        xc_rev = xc[::-1][:-1]                         # m desc
        prev_rev = chain[:-1][::-1]                    # chain[m-1], m desc

        def split_step(bud, inp, jj=jj, Kc=Kc):
            xc, prev = inp
            feas = jj <= bud[..., None]
            vals = jnp.take_along_axis(
                prev, jnp.clip(bud[..., None] - jj, 0, Kc - 1), axis=2)
            vals = jnp.where(feas, vals + xc, jnp.inf)
            best_j = jnp.argmin(vals, axis=2).astype(jnp.int32)
            return bud - best_j, best_j

        bud, best_rev = jax.lax.scan(split_step, bud, (xc_rev, prev_rev))
        split = jnp.concatenate([bud[None], best_rev[::-1]], axis=0)
        prev_split = jnp.moveaxis(split, 0, 2).reshape(B, Wi * max_c)
        prev_lc = lc

    costs = blocks[0][jnp.arange(B), root_slot - lvl_off[0], 1, k]
    blue_slots = jnp.concatenate(blue_parts, axis=1)   # blocks are ordered
    return blue_slots, costs


def slots_to_nodes(blue_slots: jax.Array, slot_of: jax.Array) -> jax.Array:
    """Slot-indexed per-node values -> node-indexed, False/0 at padding.

    ``slot_of`` maps node -> slot with ``n_slots`` at padded nodes; one
    zero row is appended so padded nodes read the neutral element.
    """
    B = blue_slots.shape[0]
    pad = jnp.concatenate(
        [blue_slots, jnp.zeros((B, 1), blue_slots.dtype)], axis=1)
    return jnp.take_along_axis(pad, slot_of, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("lvl_off", "lvl_width", "lvl_internal", "lvl_sub", "k",
                     "cap"))
def _color_packed(
    blocks: tuple,
    pk_kid: jax.Array,
    pk_par: jax.Array,
    pk_cidx: jax.Array,
    pk_load: jax.Array,
    pk_send: jax.Array,
    pk_avail: jax.Array,
    pk_rho_up: jax.Array,
    root_slot: jax.Array,
    slot_of: jax.Array,    # (B, n_max) int32 node -> slot (S at padding)
    *,
    lvl_off: tuple,
    lvl_width: tuple,
    lvl_internal: tuple,
    lvl_sub: tuple,
    k: int,
    cap: bool,
) -> tuple[jax.Array, jax.Array]:
    """Jitted :func:`_color_body` returning the node-indexed ``(B, n_max)``
    blue mask and the ``(B,)`` optimal costs — the only arrays a caller
    needs to pull off-device."""
    blue_slots, costs = _color_body(
        blocks, pk_kid, pk_par, pk_cidx, pk_load, pk_send, pk_avail,
        pk_rho_up, root_slot, lvl_off=lvl_off, lvl_width=lvl_width,
        lvl_internal=lvl_internal, lvl_sub=lvl_sub, k=k, cap=cap)
    return slots_to_nodes(blue_slots, slot_of), costs


_INPUT_CACHE: dict[tuple, tuple] = {}


def _device_inputs(f: Forest, dtype) -> tuple:
    """One host->device upload of the packed arrays (shared gather/color).

    Returns ``(kid, load, send, avail, rho, par, cidx, slot_of,
    root_slot)`` device arrays — the first five feed the gather, the rest
    the color sweep. Cached per (Forest identity, dtype): a serving loop
    re-solving one built Forest (the orchestrator replanning pattern)
    sanitizes and uploads the byte-identical arrays once, not per solve.
    The cache assumes built Forests are immutable — mutating a Forest's
    numpy arrays in place after a solve would silently reuse the stale
    device copies; rebuild via :func:`build_forest` instead (cheap: the
    per-tree structure is itself cached).
    """
    key = (id(f), np.dtype(dtype).str)
    hit = _INPUT_CACHE.get(key)
    if hit is not None and hit[0]() is f:
        return hit[1]
    R = jnp.asarray(np.where(np.isfinite(f.pk_rho_up), f.pk_rho_up, BIG),
                    dtype)
    inputs = (jnp.asarray(f.pk_kid), jnp.asarray(f.pk_load),
              jnp.asarray(f.pk_send), jnp.asarray(f.pk_avail), R,
              jnp.asarray(f.pk_par), jnp.asarray(f.pk_cidx),
              jnp.asarray(f.slot_of),
              jnp.asarray(f.slot_of[np.arange(f.batch), f.root]))
    _INPUT_CACHE[key] = (weakref.ref(f, lambda _, k=key:
                                     _INPUT_CACHE.pop(k, None)), inputs)
    return inputs


_OVERRIDE_CACHE: dict[tuple, tuple] = {}


def _override_inputs(f: Forest, dtype) -> tuple:
    """Device arrays for re-solving ``f`` under effective-rho overrides.

    Returns ``(base_edge, anc, valid, sn, real)``:

      * ``base_edge`` (B, S): each slot's own up-edge rho (the base rates
        the override scales), finite everywhere — 0 at padded slots;
      * ``anc`` (B, S, h_max+1) int32: ``anc[b, s, j]`` = slot of the
        j-th ancestor of slot s (j=0 is s itself; slot 0 past the root);
      * ``valid`` (B, S, h_max+2) bool: where ``pk_rho_up`` is finite;
      * ``sn`` / ``real`` (B, S): clipped ``slot_node`` + its validity
        mask, for gathering node-indexed scale factors into slot order.

    Together with :func:`repro.kernels.minplus.levelfold.rho_up_from_edges`
    these rebuild the packed rho-up table *on device* from scaled edge
    rates — no repacking, and the gather/color jit keys don't change, so
    one compiled executable serves every override (the congestion loop's
    whole point). Cached per (Forest identity, dtype) like
    :func:`_device_inputs`; same immutability caveat.
    """
    key = (id(f), np.dtype(dtype).str)
    hit = _OVERRIDE_CACHE.get(key)
    if hit is not None and hit[0]() is f:
        return hit[1]
    B, S = f.slot_node.shape
    bix = np.arange(B)[:, None]
    valid = np.isfinite(f.pk_rho_up)
    anc = np.zeros((B, S, f.h_max + 1), np.int32)
    cur = f.slot_node.copy()                      # node id walk, -1 done
    for j in range(f.h_max + 1):
        alive = cur >= 0
        idx = np.maximum(cur, 0)
        anc[:, :, j] = np.where(alive, f.slot_of[bix, idx], 0)
        cur = np.where(alive, f.parent[bix, idx], -1)
    inputs = (jnp.asarray(np.where(valid[:, :, 1], f.pk_rho_up[:, :, 1],
                                   0.0), dtype),
              jnp.asarray(anc), jnp.asarray(valid),
              jnp.asarray(np.maximum(f.slot_node, 0)),
              jnp.asarray(f.slot_node >= 0))
    _OVERRIDE_CACHE[key] = (weakref.ref(f, lambda _, k=key:
                                        _OVERRIDE_CACHE.pop(k, None)), inputs)
    return inputs


@jax.jit
def _override_rho(base_edge: jax.Array, anc: jax.Array, valid: jax.Array,
                  sn: jax.Array, real: jax.Array,
                  scale: jax.Array) -> jax.Array:
    """Effective packed rho-up table for a node-indexed scale factor."""
    s_slot = jnp.where(real, jnp.take_along_axis(
        scale.astype(base_edge.dtype), sn, axis=1), 1)
    return rho_up_from_edges(scaled_edges(base_edge, s_slot), anc, valid)


@jax.jit
def _override_rho_add(base_edge: jax.Array, anc: jax.Array, valid: jax.Array,
                      sn: jax.Array, real: jax.Array, scale: jax.Array,
                      extra: jax.Array, root_slot: jax.Array) -> jax.Array:
    """:func:`_override_rho` plus a per-instance additive root-edge term.

    ``extra``: (B,) — the fleet driver's shared-core transit extension on
    each instance's root up-edge (see
    :func:`~repro.kernels.minplus.levelfold.scaled_edges`); ``root_slot``:
    (B,) int32 root slot per instance.
    """
    s_slot = jnp.where(real, jnp.take_along_axis(
        scale.astype(base_edge.dtype), sn, axis=1), 1)
    edges = scaled_edges(base_edge, s_slot, extra.astype(base_edge.dtype),
                         root_slot)
    return rho_up_from_edges(edges, anc, valid)


def _gather_device(f: Forest, k: int, dtype, use_pallas: bool,
                   interpret: bool, cap: bool = True,
                   inputs: tuple | None = None) -> tuple:
    """Run the resident gather; returns the per-level device table blocks."""
    kid, load, send, avail, R = (
        _device_inputs(f, dtype) if inputs is None else inputs)[:5]
    return _gather_packed(
        kid, load, send, avail, R,
        lvl_off=f.lvl_off, lvl_width=f.lvl_width,
        lvl_internal=f.lvl_internal, lvl_sub=f.lvl_sub,
        k=k, cap=bool(cap), use_pallas=bool(use_pallas),
        interpret=bool(interpret))


def _unpack_tables(f: Forest, blocks: tuple) -> np.ndarray:
    """Per-level device blocks -> node-indexed host float64 tables.

    Debug escape hatch (``debug_tables=True``): pulls the *entire* DP
    table off-device. The default solve path never calls this. Rows
    beyond a level's ``depth+1`` are BIG (never read); index ``n_max`` is
    the all-zeros identity table sentinel children point at.
    """
    B, S = f.batch, f.n_slots
    H2 = f.h_max + 2
    K = blocks[0].shape[-1]
    Xh = np.full((B, S + 1, H2, K), BIG, np.float64)
    for d, blk in enumerate(blocks):
        o, W = f.lvl_off[d], f.lvl_width[d]
        if W:
            Xh[:, o : o + W, : d + 2] = np.asarray(blk, np.float64)
    Xh[:, S] = 0.0
    # node v of instance b lives at slot slot_of[b, v]; padded nodes point
    # at the identity slot, which is exactly the zero table color_batch
    # expects at index n_max.
    idx = np.concatenate(
        [f.slot_of, np.full((B, 1), S, np.int32)], axis=1)
    return Xh[np.arange(B)[:, None], idx]


def gather_batch(f: Forest, k: int, *, dtype=jnp.float32,
                 use_pallas: bool = False, interpret: bool = False,
                 cap: bool = True) -> np.ndarray:
    """Batched SOAR-Gather; returns *node-indexed* DP tables.

    Shape ``(B, n_max+1, h_max+2, k+1)`` float64 on host; index ``n_max``
    is the all-zeros identity slot (what sentinel children point at).
    Debug/inspection API — the solve path keeps tables on device.
    """
    return _unpack_tables(
        f, _gather_device(f, k, dtype, use_pallas, interpret, cap))


def color_batch(f: Forest, X: np.ndarray, k: int) -> np.ndarray:
    """Host-numpy SOAR-Color over *node-indexed* gathered tables.

    PR 1's traceback, kept as the ``debug_tables=True`` escape hatch and
    as the parity oracle for the on-device color: level-synchronous
    replay of Algorithm 4's budget split with the exact tie-breaking of
    the serial ``soar_color`` (blue iff strictly better; first minimizer
    of each child split), vectorized over every node of a level across
    the batch. ``X`` as produced by :func:`gather_batch` (host, float64).
    """
    B, n_max = f.mask.shape
    K = k + 1
    R = np.where(np.isfinite(f.rho_up), f.rho_up, BIG)
    blue = np.zeros((B, n_max), bool)
    budget_at = np.zeros((B, n_max), np.int64)   # budget i for T_v
    ell_at = np.ones((B, n_max), np.int64)       # dist to closest blue anc/d
    budget_at[np.arange(B), f.root] = k
    jj = np.arange(K)[None, :]

    for d, nd in enumerate(f.levels):
        valid = nd < n_max                           # real nodes only
        bv, wv = np.nonzero(valid)
        if len(bv) == 0:
            continue
        vv = nd[bv, wv]
        rows = len(vv)
        ar = np.arange(rows)
        i = budget_at[bv, vv]
        ell = ell_at[bv, vv]
        rl = R[bv, vv, ell]
        kids = f.kid[bv, vv]                         # (rows, max_c)
        # partial min-plus chains over children, red (row ell+1) and blue
        # (row 1) variants; sentinel children hit the zero identity slot.
        # Clip the red row: it only saturates for deepest-level leaves,
        # whose children are all sentinel (zero at every row).
        er = np.minimum(ell + 1, X.shape[2] - 1)
        ch_r = np.empty((rows, f.max_children, K))
        ch_b = np.empty((rows, f.max_children, K))
        ch_r[:, 0] = X[bv, kids[:, 0], er]
        ch_b[:, 0] = X[bv, kids[:, 0], 1]
        for m in range(1, f.max_children):
            ch_r[:, m] = minplus_batch(ch_r[:, m - 1], X[bv, kids[:, m], er])
            ch_b[:, m] = minplus_batch(ch_b[:, m - 1], X[bv, kids[:, m], 1])
        red_val = ch_r[ar, -1, i] + f.load[bv, vv] * rl
        can_blue = f.avail[bv, vv] & (i >= 1)
        blue_val = np.where(
            can_blue,
            ch_b[ar, -1, np.clip(i - 1, 0, K - 1)] + f.send[bv, vv] * rl,
            np.inf)
        isblue = blue_val < red_val                  # strict, as in serial
        blue[bv, vv] = isblue
        budget = i - isblue.astype(np.int64)
        lc = np.where(isblue, 1, ell + 1)
        lcc = np.minimum(lc, X.shape[2] - 1)         # saturates only for
        chain = np.where(isblue[:, None, None], ch_b, ch_r)  # sentinel reads
        # split the budget among children, last child first (mSplit replay)
        for m in range(f.max_children - 1, 0, -1):
            c = kids[:, m]
            real = c < n_max
            Xc = X[bv, c, lcc]                       # (rows, K)
            prev = chain[:, m - 1]
            feas = jj <= budget[:, None]
            vals = prev[ar[:, None], np.clip(budget[:, None] - jj, 0, K - 1)]
            vals = np.where(feas, vals + Xc, np.inf)
            best_j = np.argmin(vals, axis=1)         # first minimizer
            budget_at[bv[real], c[real]] = best_j[real]
            ell_at[bv[real], c[real]] = lc[real]
            budget = budget - np.where(real, best_j, 0)
        c = kids[:, 0]
        real = c < n_max
        budget_at[bv[real], c[real]] = budget[real]
        ell_at[bv[real], c[real]] = lc[real]
    return blue


@dataclasses.dataclass
class BatchResult:
    """Output of :func:`solve_batch` for B padded instances."""

    blue: np.ndarray | None   # (B, n_max) bool, False at padding; None
                              # in costs-only mode (color=False)
    costs: np.ndarray         # (B,) float64 — optimal phi per instance
    n: np.ndarray             # (B,) real node counts (mask key for blue)
    bytes_to_host: int = 0    # device->host traffic this solve actually paid
    tables: np.ndarray | None = None   # node-indexed DP tables; only under
                                       # the debug_tables=True escape hatch

    def blue_of(self, b: int) -> np.ndarray:
        """Unpadded blue mask of instance b."""
        if self.blue is None:
            raise ValueError("solve_batch ran with color=False")
        return self.blue[b, : int(self.n[b])]


def _jit_cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:  # pragma: no cover - private API drift across jax
        return -1


def cache_stats() -> dict:
    """Engine compile-cache telemetry.

    ``gather_cache`` / ``color_cache`` count compiled executables held by
    the two jitted sweeps; ``forests_built`` / ``distinct_layouts`` are
    packing-side counts from :func:`repro.core.forest.layout_stats` —
    with layout bucketing on, ``distinct_layouts`` (and hence the jit
    caches) stays far below ``forests_built`` on ragged fleets.
    """
    return {
        "gather_cache": _jit_cache_size(_gather_packed),
        "color_cache": _jit_cache_size(_color_packed),
        **layout_stats(),
    }


def solve_forest(
    f: Forest,
    k: int,
    *,
    options: EngineOptions | None = None,
    rho_scale: np.ndarray | jax.Array | None = None,
    rho_root_add: np.ndarray | jax.Array | None = None,
    **engine_kw,
) -> BatchResult:
    """:func:`solve_batch` for a pre-built Forest (amortizes packing).

    Default path is fully device-resident: gather and color both run on
    the accelerator and only the ``(B, n_max)`` blue masks plus ``(B,)``
    costs are transferred. Engine behavior is configured through
    ``options`` (:class:`~repro.engine.options.EngineOptions`); the old
    keyword spelling (``color=False``, ``debug_tables=True``, …) is
    removed — stray kwargs raise ``TypeError`` with the migration.

    ``rho_scale`` — a ``(B, n_max)`` node-indexed multiplier on each
    instance's *edge* rates — re-solves the prebuilt Forest under
    effective rho ``rho[v] * rho_scale[b, v]`` without repacking or
    recompiling: the packed rho-up table is rebuilt on device from the
    scaled edges (:func:`_override_rho`), every other packed array and
    the gather/color jit keys are untouched, so one cached executable
    serves all overrides. This is the congestion driver's re-solve
    primitive. Incompatible with ``debug_tables`` (the host replay reads
    the unscaled ``Forest.rho_up``).

    ``rho_root_add`` — a ``(B,)`` *additive* extension of each instance's
    root up-edge rate, applied on top of ``rho_scale`` (which it
    requires): the fleet congestion driver's shared-core transit term —
    core hops are in series with the root hop, so their penalty-weighted
    rates extend the root edge additively rather than multiplicatively.
    """
    opts = resolve_options(options, engine_kw, "solve_forest")
    if k < 0:
        raise ValueError("budget k must be non-negative")
    use_pallas = opts.use_pallas
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    inputs = _device_inputs(f, opts.dtype)
    if rho_root_add is not None and rho_scale is None:
        raise ValueError("rho_root_add extends a rho_scale re-solve; pass "
                         "rho_scale (ones for a pure additive override)")
    if rho_scale is not None:
        if opts.debug_tables:
            raise ValueError("rho_scale re-solves on device-side effective "
                             "rho; the debug_tables host replay reads the "
                             "unscaled Forest tables — pick one")
        if tuple(np.shape(rho_scale)) != (f.batch, f.n_max):
            raise ValueError(f"rho_scale shape {np.shape(rho_scale)} != "
                             f"{(f.batch, f.n_max)} (node-indexed, padded)")
        base, anc, valid, sn, real = _override_inputs(f, opts.dtype)
        if rho_root_add is None:
            R = _override_rho(base, anc, valid, sn, real,
                              jnp.asarray(rho_scale))
        else:
            if tuple(np.shape(rho_root_add)) != (f.batch,):
                raise ValueError(
                    f"rho_root_add shape {np.shape(rho_root_add)} != "
                    f"({f.batch},) (one root extension per instance)")
            R = _override_rho_add(base, anc, valid, sn, real,
                                  jnp.asarray(rho_scale),
                                  jnp.asarray(rho_root_add), inputs[8])
        inputs = inputs[:4] + (R,) + inputs[5:]
    blocks = _gather_device(f, k, opts.dtype, use_pallas, opts.interpret,
                            opts.cap, inputs)
    kid_d, load_d, send_d, avail_d, R, par_d, cidx_d, slot_d, root_d = inputs
    if not opts.color:
        # costs-only planning mode: pull back B scalars, not the tables
        roots = np.asarray(
            blocks[0][jnp.arange(f.batch), root_d - f.lvl_off[0], 1, k])
        return BatchResult(blue=None, costs=roots.astype(np.float64),
                           n=f.n.copy(), bytes_to_host=int(roots.nbytes))
    if opts.debug_tables:
        Xn = _unpack_tables(f, blocks)
        costs = Xn[np.arange(f.batch), f.root, 1, k]
        return BatchResult(blue=color_batch(f, Xn, k), costs=costs,
                           n=f.n.copy(), tables=Xn,
                           bytes_to_host=sum(int(b.nbytes) for b in blocks))
    blue_dev, costs_dev = _color_packed(
        blocks, kid_d, par_d, cidx_d, load_d, send_d, avail_d, R,
        root_d, slot_d,
        lvl_off=f.lvl_off, lvl_width=f.lvl_width,
        lvl_internal=f.lvl_internal, lvl_sub=f.lvl_sub, k=k,
        cap=bool(opts.cap))
    blue = np.asarray(blue_dev)
    costs = np.asarray(costs_dev)
    return BatchResult(blue=blue, costs=costs.astype(np.float64),
                       n=f.n.copy(),
                       bytes_to_host=int(blue.nbytes + costs.nbytes))


def solve_batch(
    trees: Sequence[Tree],
    loads: Sequence[np.ndarray],
    k: int,
    avail: Sequence[np.ndarray] | None = None,
    *,
    options: EngineOptions | None = None,
    **engine_kw,
) -> BatchResult:
    """Solve B phi-BIC instances at once; per-instance output contract of
    :func:`repro.core.soar.soar` (optimal costs, at-most-k blue masks).

    Instances may be ragged (different n, height, children); the packed
    layout is bucketed (see :func:`repro.core.forest.build_forest`), so
    batches of similar shape share one compiled executable. Pass engine
    behavior as ``options=EngineOptions(...)`` — ``use_pallas=None``
    (the default) auto-dispatches: fused level-fold Pallas kernel on
    TPU, fused jnp elsewhere. Everything stays on device; see
    :func:`solve_forest`.
    """
    opts = resolve_options(options, engine_kw, "solve_batch")
    return solve_forest(build_forest(trees, loads, avail), k, options=opts)
