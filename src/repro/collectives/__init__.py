from .schedule import (CongestionPlan, FleetPlan, ReduceProgram, TenantPlan,
                       build_program, plan, plan_batch, plan_congestion,
                       plan_fleet)
from .topology import (ClusterTopology, Fleet, build_fleet, chip_level_tree,
                       degrade_links, degrade_switches, fail_devices,
                       fail_switches, fleet_tree)
from .tree_allreduce import tree_allreduce, tree_allreduce_tree

__all__ = [
    "CongestionPlan", "FleetPlan", "ReduceProgram", "TenantPlan",
    "build_program", "plan", "plan_batch", "plan_congestion", "plan_fleet",
    "ClusterTopology", "Fleet", "build_fleet", "chip_level_tree",
    "fleet_tree", "fail_devices", "fail_switches", "degrade_links",
    "degrade_switches",
    "tree_allreduce", "tree_allreduce_tree",
]
