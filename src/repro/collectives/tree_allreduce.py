"""shard_map executor for the SOAR reduction program.

Runs the paper's Reduce (Algorithm 1) as an actual JAX collective: red
switches forward message buffers upward (ppermute rounds), blue switches
collapse their buffer to a single partial sum, and the destination performs
the final aggregation + broadcast. Semantically equivalent to psum — proven
by tests — while its *network cost* equals the placement's phi, so the
SOAR-optimal placement minimizes the interconnect time of this collective.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .schedule import CompressOp, PermuteRound, ReduceProgram

try:  # JAX >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _apply_program(x, prog: ReduceProgram, axis: str):
    """x: local block (1, D) from shard_map -> flattened (D,)."""
    x = x.reshape(-1)
    d = x.shape[-1]
    dev = jax.lax.axis_index(axis)
    buf = jnp.zeros((prog.n_slots, d), x.dtype).at[0].set(x)
    for op in prog.ops:
        if isinstance(op, PermuteRound):
            sent = buf[: op.slab]
            recv = jax.lax.ppermute(sent, axis, op.perm)
            off = jnp.asarray(op.recv_offset)[dev]
            cnt = jnp.asarray(op.recv_count)[dev]
            sl = jnp.arange(op.slab)
            mask = (sl < cnt)[:, None]
            idx = jnp.clip(off + sl, 0, prog.n_slots - 1)
            buf = buf.at[idx].add(jnp.where(mask, recv, 0))
        else:  # CompressOp
            flag = jnp.asarray(op.flag)[dev]
            width = jnp.asarray(op.width)[dev]
            summask = (jnp.arange(prog.n_slots) < width)[:, None]
            s = (buf * summask.astype(buf.dtype)).sum(0)
            compressed = jnp.zeros_like(buf).at[0].set(s)
            buf = jnp.where(flag, compressed, buf)
    # destination d: aggregate the root's outgoing messages, broadcast back
    rootmask = (jnp.arange(prog.n_slots) < prog.root_count)[:, None]
    local = (buf * rootmask.astype(buf.dtype)).sum(0)
    local = jnp.where(dev == prog.root_home, local, 0)
    return jax.lax.psum(local, axis)


def reduce_local(x, prog: ReduceProgram, axis: str = "data"):
    """SOAR-reduce a per-device value *inside* an existing shard_map body.

    x: the device-local array (any shape); returns the global sum,
    replicated. Used by the training driver to reduce gradients with the
    SOAR program while the rest of the step stays in the same shard_map.
    """
    out = _apply_program(x.reshape(1, -1), prog, axis)
    return out.reshape(x.shape)


def tree_allreduce(x, prog: ReduceProgram, mesh, axis: str = "data"):
    """AllReduce-sum of x over `axis` following the SOAR program.

    x: (n_dev_along_axis, D) global view, or any array whose leading dim is
    sharded over `axis`.
    """
    fn = _shard_map(
        functools.partial(_apply_program, prog=prog, axis=axis),
        mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(axis),
        out_specs=jax.sharding.PartitionSpec(),
    )
    return fn(x)


def tree_allreduce_tree(grads, prog: ReduceProgram, mesh, axis: str = "data"):
    """Apply the SOAR collective to every leaf of a gradient pytree."""

    def one(g):
        flat = g.reshape(1, -1) if g.ndim else g.reshape(1, 1)
        out = tree_allreduce(flat, prog, mesh, axis)
        return out.reshape(g.shape)

    return jax.tree.map(one, grads)
