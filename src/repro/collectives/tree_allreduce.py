"""shard_map executor for the SOAR reduction program.

Runs the paper's Reduce (Algorithm 1) as an actual JAX collective: red
switches forward message buffers upward (ppermute rounds), blue switches
collapse their buffer to a single partial sum, and the destination performs
the final aggregation + broadcast. Semantically equivalent to psum — proven
by tests — while its *network cost* equals the placement's phi, so the
SOAR-optimal placement minimizes the interconnect time of this collective.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .schedule import CompactOp, CompressOp, FoldOp, PermuteRound, ReduceProgram

try:  # JAX >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _left_fold(buf, start, width, hi):
    """Strict sequential left fold of ``buf[start : start+width]``.

    Aggregations run as ``((s_0 + s_1) + s_2) + ...`` — a fixed summation
    order, so a degraded switch's partial fold is a *prefix* of the
    fault-free fold and the parent-side completion (:class:`FoldOp`)
    reproduces the pristine sum bit-for-bit. ``hi`` is the static loop
    bound; slots past ``width`` contribute exact zeros.
    """
    n = buf.shape[0]
    init = jnp.take(buf, jnp.clip(start, 0, n - 1), axis=0)

    def body(j, acc):
        slot = jnp.take(buf, jnp.clip(start + j, 0, n - 1), axis=0)
        return acc + jnp.where(j < width, slot, 0)

    return jax.lax.fori_loop(1, max(hi, 1), body, init)


def _apply_program(x, prog: ReduceProgram, axis: str):
    """x: local block (1, D) from shard_map -> flattened (D,)."""
    x = x.reshape(-1)
    d = x.shape[-1]
    dev = jax.lax.axis_index(axis)
    buf = jnp.zeros((prog.n_slots, d), x.dtype).at[0].set(x)
    sl = jnp.arange(prog.n_slots)
    for op in prog.ops:
        if isinstance(op, PermuteRound):
            sent = buf[: op.slab]
            recv = jax.lax.ppermute(sent, axis, op.perm)
            off = jnp.asarray(op.recv_offset)[dev]
            cnt = jnp.asarray(op.recv_count)[dev]
            rsl = jnp.arange(op.slab)
            mask = (rsl < cnt)[:, None]
            idx = jnp.clip(off + rsl, 0, prog.n_slots - 1)
            buf = buf.at[idx].add(jnp.where(mask, recv, 0))
        elif isinstance(op, CompressOp):
            flag = jnp.asarray(op.flag)[dev]
            width = jnp.asarray(op.width)[dev]
            s = _left_fold(buf, 0, width, prog.n_slots)
            # fold lands in slot 0, slots [1, width) clear; slots >= width
            # keep a degraded switch's raw overflow for the spill upward
            folded = jnp.where((sl == 0)[:, None], s[None, :],
                               jnp.where((sl < width)[:, None], 0, buf))
            buf = jnp.where(flag, folded, buf)
        elif isinstance(op, FoldOp):
            start = jnp.asarray(op.start)[dev]
            cnt = jnp.asarray(op.count)[dev]
            # continue the child's fold: acc starts at its partial P'.
            # Idle devices (cnt == 0) keep their buffer bitwise untouched.
            acc = _left_fold(buf, start, cnt, op.span)
            buf = jnp.where(cnt > 0, buf.at[start].set(acc), buf)
        else:  # CompactOp: static gather back to the fault-free layout
            idx = jnp.asarray(op.src)[dev]
            gathered = jnp.take(buf, jnp.clip(idx, 0, prog.n_slots - 1),
                                axis=0)
            buf = jnp.where((idx >= 0)[:, None], gathered, 0)
    # destination d: aggregate the root's outgoing messages (same strict
    # left fold — completing a degraded root's spill), broadcast back
    local = _left_fold(buf, 0, prog.root_count, prog.root_count)
    local = jnp.where(dev == prog.root_home, local, 0)
    return jax.lax.psum(local, axis)


def reduce_local(x, prog: ReduceProgram, axis: str = "data"):
    """SOAR-reduce a per-device value *inside* an existing shard_map body.

    x: the device-local array (any shape); returns the global sum,
    replicated. Used by the training driver to reduce gradients with the
    SOAR program while the rest of the step stays in the same shard_map.
    """
    out = _apply_program(x.reshape(1, -1), prog, axis)
    return out.reshape(x.shape)


def tree_allreduce(x, prog: ReduceProgram, mesh, axis: str = "data"):
    """AllReduce-sum of x over `axis` following the SOAR program.

    x: (n_dev_along_axis, D) global view, or any array whose leading dim is
    sharded over `axis`.
    """
    fn = _shard_map(
        functools.partial(_apply_program, prog=prog, axis=axis),
        mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(axis),
        out_specs=jax.sharding.PartitionSpec(),
    )
    return fn(x)


def tree_allreduce_tree(grads, prog: ReduceProgram, mesh, axis: str = "data"):
    """Apply the SOAR collective to every leaf of a gradient pytree."""

    def one(g):
        flat = g.reshape(1, -1) if g.ndim else g.reshape(1, 1)
        out = tree_allreduce(flat, prog, mesh, axis)
        return out.reshape(g.shape)

    return jax.tree.map(one, grads)
