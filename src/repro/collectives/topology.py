"""Cluster reduction-tree topology: the paper's T overlaid on a TPU fleet.

Gradient reduction for one model-parallel column flows over the (pod, data)
mesh axes. Physically that is a tree: chips -> rack/host reducers -> pod
spines -> the cross-pod destination d. Link rates are heterogeneous (ICI >>
DCN), which is exactly the paper's arbitrary-omega setting; the bounded
budget k models how many rack/pod reduction points a tenant may claim
(Sec. 5.2 multi-workload capacity).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.tree import DEST, Tree

# Relative per-message transmission times (rho = 1/rate): a message crossing
# a DCN hop costs ~16x an ICI hop (50 GB/s/link ICI vs ~3 GB/s/link-share DCN).
RHO_ICI = 1.0
RHO_RACK = 2.0
RHO_DCN = 16.0


@dataclasses.dataclass(frozen=True)
class ClusterTopology:
    tree: Tree
    device_leaf: np.ndarray        # device id -> leaf switch id
    load: np.ndarray               # per-switch load (grad shards entering)
    blocked: np.ndarray | None = None  # switches whose aggregation plane is
                                       # down (forwarding still works); they
                                       # leave the candidate set Lambda
    cap_scale: np.ndarray | None = None  # per-switch remaining aggregation-
                                         # capacity fraction a(s) in [0, 1];
                                         # None = all pristine. 0 composes
                                         # with blocked (the frac->0 limit)

    @property
    def n_devices(self) -> int:
        return len(self.device_leaf)

    def candidates(self, avail: np.ndarray | None = None) -> np.ndarray | None:
        """Availability mask Lambda after removing blocked switches.

        ``avail`` is an optional extra mask (e.g. the orchestrator's
        residual-capacity snapshot); the result is its intersection with
        the non-blocked switches — and the switches whose aggregation
        capacity has degraded all the way to zero, which is the same
        fault expressed continuously — or ``None`` when neither
        constrains. A mask whose shape is not one flag per switch raises
        here, at the planner boundary, instead of broadcasting somewhere
        in the engine.
        """
        if avail is not None:
            avail = np.asarray(avail, bool)
            if avail.shape != (self.tree.n,):
                raise ValueError(f"avail shape {avail.shape} != "
                                 f"({self.tree.n},) — one flag per switch")
        cand = None
        if self.blocked is not None:
            cand = ~self.blocked
        if self.cap_scale is not None:
            dead = np.asarray(self.cap_scale, np.float64) <= 0.0
            if dead.any():
                cand = ~dead if cand is None else cand & ~dead
        if cand is None:
            return avail
        if avail is None:
            return cand
        return avail & cand


def fleet_tree(n_pods: int = 2, racks_per_pod: int = 4,
               chips_per_rack: int = 4) -> ClusterTopology:
    """Reduction tree: root spine -> pods -> racks; chips attach to racks.

    Chips are *servers* in the paper's model (they produce the messages);
    racks/pods/spine are the switches, some of which may aggregate.
    """
    parent, rho = [], []
    root = 0
    parent.append(DEST)
    rho.append(RHO_DCN)            # spine -> destination (cross-cluster)
    pods = []
    for p in range(n_pods):
        pods.append(len(parent))
        parent.append(root)
        rho.append(RHO_DCN)        # pod -> spine crosses the DCN
    racks = []
    for p in pods:
        for r in range(racks_per_pod):
            racks.append(len(parent))
            parent.append(p)
            rho.append(RHO_RACK)   # rack -> pod aggregation link
    t = Tree(np.asarray(parent, np.int32), np.asarray(rho))
    load = np.zeros(t.n, np.int64)
    device_leaf = []
    for r in racks:
        for c in range(chips_per_rack):
            device_leaf.append(r)
            load[r] += 1           # each chip contributes one gradient shard
    return ClusterTopology(tree=t, device_leaf=np.asarray(device_leaf),
                           load=load)


def chip_level_tree(n_pods: int = 2, racks_per_pod: int = 4,
                    chips_per_rack: int = 4) -> ClusterTopology:
    """Variant where each chip is its own leaf switch (ToR-of-one); used by
    the shard_map executor, whose message homes live on devices."""
    base = fleet_tree(n_pods, racks_per_pod, chips_per_rack)
    parent = list(base.tree.parent)
    rho = list(base.tree.rho)
    load = list(base.load)
    device_leaf = []
    for dev, rack in enumerate(base.device_leaf):
        leaf = len(parent)
        parent.append(int(rack))
        rho.append(RHO_ICI)        # chip -> rack ICI link
        load[int(rack)] = 0
        load.append(1)
        device_leaf.append(leaf)
    t = Tree(np.asarray(parent, np.int32), np.asarray(rho))
    return ClusterTopology(tree=t, device_leaf=np.asarray(device_leaf),
                           load=np.asarray(load, np.int64))


def fail_devices(topo: ClusterTopology, dead: list[int]) -> ClusterTopology:
    """Remove failed chips from the reduction tree (runtime FT path).

    Dead chips stop producing messages; switches whose whole subtree died
    still exist but carry zero load (SOAR then never wastes budget there —
    the zero-load refinement of DESIGN.md §8). Duplicate ids in ``dead``
    are collapsed to one failure; a device that is already failed in
    ``topo`` (``device_leaf[d] == -1``) raises — its leaf's load was
    already released, and ``load[-1]`` would silently drain the *last*
    switch's load instead.
    """
    load = topo.load.copy()
    device_leaf = topo.device_leaf.copy()
    for d in dict.fromkeys(int(d) for d in dead):     # dedupe, keep order
        if not 0 <= d < len(device_leaf):
            raise ValueError(f"device {d} out of range "
                             f"[0, {len(device_leaf)})")
        if device_leaf[d] < 0:
            raise ValueError(f"device {d} is already failed")
        load[device_leaf[d]] -= 1
        device_leaf[d] = -1
    return ClusterTopology(tree=topo.tree, device_leaf=device_leaf, load=load,
                           blocked=topo.blocked, cap_scale=topo.cap_scale)


def fail_switches(topo: ClusterTopology, dead: list[int],
                  isolate: bool = False) -> ClusterTopology:
    """A switch's aggregation plane fails (runtime fault-domain path).

    Default semantics are the in-network-computing fault model (P4COM's
    fallback transport): the switch keeps *forwarding* — the tree, its
    loads and all paths are unchanged — but it can never aggregate again,
    so it leaves the candidate set Lambda (``blocked`` mask; the planner
    paths intersect it into ``avail``).

    ``isolate=True`` models the switch dying outright: every device whose
    leaf lies in a dead switch's subtree is disconnected, so the subtree's
    load drains exactly like :func:`fail_devices` (the tree object stays —
    SOAR simply never spends budget on zero-load subtrees) and the subtree
    re-homes nothing upward.

    Duplicate ids collapse to one failure; a switch already blocked in
    ``topo`` raises — same validate-then-apply discipline as
    :func:`fail_devices`.
    """
    t = topo.tree
    blocked = (np.zeros(t.n, bool) if topo.blocked is None
               else topo.blocked.copy())
    dead = list(dict.fromkeys(int(s) for s in dead))   # dedupe, keep order
    for s in dead:
        if not 0 <= s < t.n:
            raise ValueError(f"switch {s} out of range [0, {t.n})")
        if blocked[s]:
            raise ValueError(f"switch {s} is already failed")
    for s in dead:
        blocked[s] = True
    load = topo.load
    device_leaf = topo.device_leaf
    if isolate:
        # descendants of any dead switch (including the switch itself)
        dead_sub = np.zeros(t.n, bool)
        dead_sub[dead] = True
        for v in t.topo:                       # root first: parent resolved
            p = t.parent[v]
            if p != DEST and dead_sub[p]:
                dead_sub[v] = True
        gone = [d for d, leaf in enumerate(device_leaf)
                if leaf >= 0 and dead_sub[leaf]]
        if gone:
            interim = fail_devices(
                dataclasses.replace(topo, blocked=None), gone)
            load, device_leaf = interim.load, interim.device_leaf
    return ClusterTopology(tree=t, device_leaf=device_leaf, load=load,
                           blocked=blocked, cap_scale=topo.cap_scale)


def degrade_links(topo: ClusterTopology,
                  rates: dict[int, float]) -> ClusterTopology:
    """Scale the up-link rate of the given switches (runtime fault path).

    ``rates[v]`` is the remaining *rate* fraction of edge ``(v, p(v))`` —
    0.5 means the link runs at half its bandwidth, so the reciprocal rate
    doubles (``rho[v] /= rates[v]``); values above 1 speed a link up
    (recovery relative to an already-degraded topology). The tree is
    rebuilt with the new rho — this is exactly the ``rho`` the placement
    DP optimizes over, so replanning through the engine picks it up with
    no special casing.
    """
    t = topo.tree
    rho = t.rho.copy()
    for v, f in rates.items():
        v, f = int(v), float(f)
        if not 0 <= v < t.n:
            raise ValueError(f"switch {v} out of range [0, {t.n})")
        if not np.isfinite(f) or f <= 0:
            raise ValueError(f"rate fraction for switch {v} must be a "
                             f"positive finite number, got {f}")
        rho[v] = rho[v] / f
    return dataclasses.replace(topo, tree=Tree(t.parent, rho))


def degrade_switches(topo: ClusterTopology,
                     scales: dict[int, float]) -> ClusterTopology:
    """Scale the aggregation capacity a(s) of the given switches.

    ``scales[s]`` in ``[0, 1]`` is the remaining fraction of switch
    ``s``'s nominal aggregation capacity — the P4COM/SwitchAgg model
    where a switch's in-network compute is a per-switch *resource* that
    degrades gradually (memory pressure, partial pipeline loss), not a
    boolean. Scales compose multiplicatively with an existing
    ``cap_scale`` (two half-capacity events leave a quarter), mirroring
    :func:`degrade_links`. The ``frac -> 0`` limit composes with
    ``blocked`` / :func:`fail_switches`: a zero-capacity switch leaves
    the candidate set Lambda (see :meth:`ClusterTopology.candidates`)
    while forwarding keeps working, exactly like a blocked switch.

    Validation is all-before-apply: a bad id or a non-finite / out-of-
    range fraction raises before any state is built.
    """
    t = topo.tree
    scale = (np.ones(t.n, np.float64) if topo.cap_scale is None
             else np.asarray(topo.cap_scale, np.float64).copy())
    items = [(int(s), float(f)) for s, f in scales.items()]
    for s, f in items:
        if not 0 <= s < t.n:
            raise ValueError(f"switch {s} out of range [0, {t.n})")
        if not np.isfinite(f) or f < 0 or f > 1:
            raise ValueError(f"capacity scale for switch {s} must be a "
                             f"finite fraction in [0, 1], got {f}")
    for s, f in items:
        scale[s] = scale[s] * f
    return dataclasses.replace(topo, cap_scale=scale)


@dataclasses.dataclass(frozen=True)
class Fleet:
    """N aggregation trees hanging off a shared core (multi-tree setting).

    Each tree is a full :class:`ClusterTopology`; the core is a flat set of
    C extra links with per-link reciprocal rates ``core_rho``. Every
    root-crossing message of a tenant on tree g additionally transits the
    core links in ``core_path[g]`` (its root -> destination path through
    the shared core), which is how tenants on *different* trees become
    congestion-coupled: they meet on shared core link ids.

    Link ids live in one **global link-id space** so per-link traffic from
    different trees lands in one congestion profile::

        [0, n_0)                      tree 0's switch up-links
        [off_g, off_g + n_g)          tree g's up-links, off_g = sum n_<g
        [core_offset, core_offset+C)  the shared-core links

    The single-tree case is the degenerate ``N=1, C=0`` fleet
    (:meth:`single`), not a parallel code path.
    """

    topos: tuple[ClusterTopology, ...]
    core_rho: np.ndarray                    # (C,) reciprocal rates; C may be 0
    core_path: tuple[tuple[int, ...], ...]  # per tree: core link ids crossed

    def __post_init__(self):
        if not self.topos:
            raise ValueError("empty fleet")
        core_rho = np.asarray(self.core_rho, np.float64)
        object.__setattr__(self, "core_rho", core_rho)
        if core_rho.ndim != 1:
            raise ValueError(f"core_rho must be 1-D, got shape "
                             f"{core_rho.shape}")
        if core_rho.size and not (np.isfinite(core_rho).all()
                                  and (core_rho > 0).all()):
            raise ValueError("core_rho entries must be positive and finite")
        if len(self.core_path) != len(self.topos):
            raise ValueError(f"{len(self.core_path)} core paths for "
                             f"{len(self.topos)} trees")
        C = core_rho.size
        path = tuple(tuple(int(c) for c in p) for p in self.core_path)
        object.__setattr__(self, "core_path", path)
        for g, p in enumerate(path):
            if len(set(p)) != len(p):
                raise ValueError(f"core path of tree {g} repeats a link: {p}")
            for c in p:
                if not 0 <= c < C:
                    raise ValueError(f"core link {c} on tree {g}'s path out "
                                     f"of range [0, {C})")

    @property
    def n_trees(self) -> int:
        return len(self.topos)

    @property
    def n_core(self) -> int:
        return int(self.core_rho.size)

    @property
    def link_offsets(self) -> tuple[int, ...]:
        """Global-link-id segment start of each tree's up-links."""
        offs, s = [], 0
        for tp in self.topos:
            offs.append(s)
            s += tp.tree.n
        return tuple(offs)

    @property
    def core_offset(self) -> int:
        """First global link id of the shared-core segment."""
        return sum(tp.tree.n for tp in self.topos)

    @property
    def n_links(self) -> int:
        return self.core_offset + self.n_core

    @classmethod
    def single(cls, topo: ClusterTopology) -> "Fleet":
        """The degenerate one-tree fleet (no shared core)."""
        return cls(topos=(topo,), core_rho=np.zeros(0, np.float64),
                   core_path=((),))


def build_fleet(n_trees: int = 2, n_pods: int = 2, racks_per_pod: int = 4,
                chips_per_rack: int = 4, *, spine_rho: float = RHO_DCN,
                uplink_rho: float | None = None) -> Fleet:
    """N :func:`fleet_tree` topologies sharing one core spine link.

    Every tree's root-crossing traffic transits a single shared DCN spine
    (core link with rate ``spine_rho``) — the minimal fleet in which trees
    contend. ``uplink_rho`` additionally gives each tree a dedicated core
    up-link (tree root -> spine) on its path, modelling per-tree core
    attachment capacity.
    """
    if n_trees < 1:
        raise ValueError(f"need at least one tree, got {n_trees}")
    topos = tuple(fleet_tree(n_pods, racks_per_pod, chips_per_rack)
                  for _ in range(n_trees))
    if uplink_rho is None:
        core_rho = np.asarray([spine_rho], np.float64)
        core_path = tuple((0,) for _ in range(n_trees))
    else:
        core_rho = np.asarray([uplink_rho] * n_trees + [spine_rho],
                              np.float64)
        core_path = tuple((g, n_trees) for g in range(n_trees))
    return Fleet(topos=topos, core_rho=core_rho, core_path=core_path)
