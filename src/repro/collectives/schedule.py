"""SOAR placement -> static reduction program (the collective schedule).

Builds, for a cluster tree + blue placement, the exact message-passing
program a shard_map executor runs: which device sends which buffer slots to
whom in each round, and where partial sums are materialized. All counts are
static (topology, loads and coloring are known), so the program is a plain
Python object baked into the jitted collective.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.reduce import messages_up, messages_up_degraded, phi_degraded
from ..core import baselines
from ..engine.options import EngineOptions, resolve_options
from .topology import ClusterTopology, Fleet


def _check_capacity(capacity, n: int, where: str):
    """Boundary validation of a per-switch capacity vector: shape (n,),
    finite, non-negative. Returns the float64 copy the engine consumes."""
    c = np.asarray(capacity, np.float64)
    if c.shape != (n,):
        raise ValueError(f"{where}: capacity shape {c.shape} != ({n},)")
    if not np.all(np.isfinite(c)) or np.any(c < 0):
        raise ValueError(f"{where}: capacity must be finite and "
                         "non-negative")
    return c


def _check_residual(residual, n: int, where: str):
    """Boundary validation of a per-switch residual-capacity ledger:
    shape (n,), finite, integer-valued, non-negative. Returns the int64
    copy the engine's hard-admission path consumes."""
    r = np.asarray(residual)
    if r.shape != (n,):
        raise ValueError(f"{where}: residual shape {r.shape} != ({n},)")
    rf = r.astype(np.float64)
    if not np.all(np.isfinite(rf)) or np.any(rf != np.floor(rf)):
        raise ValueError(f"{where}: residual must be integer-valued and "
                         "finite")
    if np.any(rf < 0):
        raise ValueError(f"{where}: residual must be non-negative")
    return r.astype(np.int64)


@dataclasses.dataclass
class PermuteRound:
    perm: list                      # [(src_dev, dst_dev)]
    slab: int                       # slots sent per pair
    recv_offset: np.ndarray         # (n_dev,) slot offset at receiver
    recv_count: np.ndarray          # (n_dev,) valid incoming slots


@dataclasses.dataclass
class CompressOp:
    flag: np.ndarray                # (n_dev,) bool: device compresses now
    width: np.ndarray               # (n_dev,) slots folded into slot 0
                                    # (strict left fold; slots [1, width)
                                    # are cleared, slots >= width kept —
                                    # a degraded switch's raw overflow)


@dataclasses.dataclass
class FoldOp:
    """Host completion of a degraded child's spilled aggregation.

    The child delivered ``[P', x_m, .., x_{w-1}]`` (its partial fold plus
    the raw overflow); the parent's home continues the *same* left fold —
    ``((P' + x_m) + ...) + x_{w-1}`` — writing the completed sum back at
    the span's first slot. Because P' is the prefix of the fault-free
    fold, the result is bit-identical to the pristine aggregation.
    """
    start: np.ndarray               # (n_dev,) first slot of the span
    count: np.ndarray               # (n_dev,) slots in the span (0 = idle)
    span: int                       # static loop bound (max count)


@dataclasses.dataclass
class CompactOp:
    """Static per-device slot gather: ``buf[i] = buf[src[dev, i]]``.

    ``src[dev, i] == -1`` zero-fills. Restores the *fault-free* slot
    layout after spilled deliveries were folded (and clears the stale
    overflow slots), so every op downstream of a degraded level is the
    byte-for-byte pristine program.
    """
    src: np.ndarray                 # (n_dev, n_slots) int32 gather map


@dataclasses.dataclass
class ReduceProgram:
    n_dev: int
    n_slots: int
    ops: list                       # PermuteRound | CompressOp | FoldOp
                                    # | CompactOp
    root_home: int
    root_count: int
    utilization: float              # phi of the underlying placement
                                    # (phi_degraded under reduced capacity)
    total_network_messages: int     # logical messages (== sum msgs_up,
                                    # incl. spilled overflow)


def build_program(topo: ClusterTopology, blue: np.ndarray) -> ReduceProgram:
    t = topo.tree
    load = topo.load
    blue = np.asarray(blue, bool)
    if topo.blocked is not None and np.any(blue & topo.blocked):
        raise ValueError("blue placement aggregates at a failed switch")
    scale = (None if topo.cap_scale is None
             else np.asarray(topo.cap_scale, np.float64))
    if scale is not None and np.any(blue & (scale <= 0.0)):
        raise ValueError("blue placement aggregates at a zero-capacity "
                         "switch")
    if any(load[v] > 0 and len(t.children[v]) > 0 for v in range(t.n)):
        raise ValueError("executor supports leaf-only loads")
    n_dev = topo.n_devices
    msgs = messages_up(t, load, blue)      # fault-free out-counts

    # degraded execution: a blue switch at capacity scale a < 1 folds only
    # the first m = agg_width(w, a) of its w inputs and spills the
    # o = w - m overflow raw one hop up, where the parent's *host*
    # completes the same left fold. out_dl is what each switch actually
    # sends (msgs + its own overflow); everything above a spill carries
    # the fault-free count again.
    out_dl = messages_up_degraded(t, load, blue, scale)
    over = out_dl - msgs

    # homes: leaf -> its device; internal -> home of first nonempty child
    home = np.full(t.n, -1, np.int64)
    for dev, leaf in enumerate(topo.device_leaf):
        if leaf >= 0:
            home[leaf] = dev
    for v in t.topo[::-1]:
        if home[v] < 0:
            for c in t.children[v]:
                if home[c] >= 0:
                    home[v] = home[c]
                    break

    ops: list = []
    compacts: list[tuple[CompactOp, dict]] = []   # pad rows at the end
    n_slots = 1
    # process internal switches level by level (deepest parents first)
    order = [v for v in t.topo[::-1] if t.children[v]]
    level_of = {v: int(t.depth[v]) for v in range(t.n)}
    for depth in sorted({level_of[v] for v in order}, reverse=True):
        parents = [v for v in order if level_of[v] == depth]
        maxc = max(len(t.children[v]) for v in parents)
        for ci in range(1, maxc):   # child 0 lives at the parent's home
            perm, roff, rcnt = [], np.zeros(n_dev, np.int64), np.zeros(n_dev, np.int64)
            slab = 0
            for p in parents:
                kids = [c for c in t.children[p] if home[c] >= 0]
                if ci >= len(kids):
                    continue
                c = kids[ci]
                cnt = int(out_dl[c])
                if cnt == 0 or home[c] == home[p]:
                    continue
                off = int(load[p]) + sum(int(out_dl[kids[j]])
                                         for j in range(ci))
                perm.append((int(home[c]), int(home[p])))
                roff[home[p]] = off
                rcnt[home[p]] = cnt
                slab = max(slab, cnt)
                n_slots = max(n_slots, off + cnt)
            if perm:
                ops.append(PermuteRound(perm, slab, roff, rcnt))
        # host completion of spilled children: fold each degraded child's
        # [P', overflow...] span in delivery order, then compact back to
        # the fault-free slot layout so every op above this level is the
        # byte-for-byte pristine program
        spans = {}                  # parent -> [(child, dl_off, dl_cnt)]
        spilled = {}                # parent -> [(dl_off, dl_cnt)]
        for p in parents:
            kids = [c for c in t.children[p] if home[c] >= 0]
            off, sp, spl = int(load[p]), [], []
            for c in kids:
                cnt = int(out_dl[c])
                sp.append((c, off, cnt))
                if over[c] > 0 and cnt > 0:
                    spl.append((off, cnt))
                    n_slots = max(n_slots, off + cnt)
                off += cnt
            spans[p] = sp
            if spl:
                spilled[p] = spl
        fold_round = 0
        while any(fold_round < len(spl) for spl in spilled.values()):
            start = np.zeros(n_dev, np.int64)
            count = np.zeros(n_dev, np.int64)
            for p, spl in spilled.items():
                if fold_round < len(spl):
                    off_c, cnt = spl[fold_round]
                    start[home[p]] = off_c
                    count[home[p]] = cnt
            ops.append(FoldOp(start, count, int(count.max())))
            fold_round += 1
        if spilled:
            rows = {}
            for p in spilled:
                row = []
                for i in range(int(load[p])):
                    row.append(i)
                for c, dl_off, _ in spans[p]:
                    # a spilled child collapsed to 1 message at dl_off;
                    # others map their whole fault-free span
                    for j in range(int(msgs[c])):
                        row.append(dl_off + j)
                rows[int(home[p])] = np.asarray(row, np.int32)
            op = CompactOp(src=None)
            compacts.append((op, rows))
            ops.append(op)
        # compress at blue parents of this level (fault-free widths; a
        # degraded parent folds only its first `total - over` inputs)
        flag = np.zeros(n_dev, bool)
        width = np.ones(n_dev, np.int64)
        any_comp = False
        self_rows = {}
        for p in parents:
            if blue[p] and home[p] >= 0:
                kids = [c for c in t.children[p] if home[c] >= 0]
                total = int(load[p]) + sum(int(msgs[c]) for c in kids)
                if total > 1:
                    m = total - int(over[p])
                    flag[home[p]] = True
                    width[home[p]] = m
                    n_slots = max(n_slots, total)
                    any_comp = True
                    if over[p] > 0:
                        # [P' at 0, raw x_m..x_{w-1}] -> contiguous
                        # [P', x_m, ..] for the delivery upward
                        row = [0] + [m + j for j in range(int(over[p]))]
                        self_rows[int(home[p])] = np.asarray(row, np.int32)
        if any_comp:
            ops.append(CompressOp(flag, width))
        if self_rows:
            op = CompactOp(src=None)
            compacts.append((op, self_rows))
            ops.append(op)

    # finalize compact gather maps now that n_slots is known: uninvolved
    # devices keep an identity row; involved rows zero-fill (-1) past the
    # mapped extent, clearing stale overflow slots
    for op, rows in compacts:
        src = np.tile(np.arange(n_slots, dtype=np.int32), (n_dev, 1))
        for dev, row in rows.items():
            src[dev, : len(row)] = row
            src[dev, len(row):] = -1
        op.src = src

    r = t.root
    return ReduceProgram(
        n_dev=n_dev,
        n_slots=n_slots,
        ops=ops,
        root_home=int(home[r]),
        root_count=int(out_dl[r]),
        utilization=phi_degraded(t, load, blue, scale),
        total_network_messages=int(out_dl.sum()),
    )


@dataclasses.dataclass(frozen=True)
class TenantPlan:
    """One planned tenant: the blue mask, its compiled program, its cost.

    ``cost`` is the placement's utilization (phi on the original rho, the
    same number :class:`ReduceProgram` carries). Iterable-unpacking keeps
    the historical ``blue, program = plan(...)`` spelling working."""

    blue: np.ndarray
    program: ReduceProgram
    cost: float

    def __iter__(self):
        return iter((self.blue, self.program))


@dataclasses.dataclass(frozen=True)
class CongestionPlan:
    """:func:`plan_congestion`'s result: per-tenant plans + diagnostics.

    ``plans`` is a list of :class:`TenantPlan` in tenant order; ``result``
    the driver's ``CongestionResult`` (baseline vs achieved congestion,
    rounds, history, transfer accounting). Unpacks as the historical
    ``planned, res = plan_congestion(...)`` pair."""

    plans: list
    result: object                 # repro.engine.CongestionResult

    def __iter__(self):
        return iter((self.plans, self.result))

    @property
    def max_congestion(self) -> float:
        return self.result.max_congestion

    @property
    def improvement(self) -> float:
        return self.result.improvement


def plan(topo: ClusterTopology, k: int, avail: np.ndarray | None = None,
         strategy: str = "soar", *, options: EngineOptions | None = None,
         **engine_kw) -> TenantPlan:
    """Choose the blue set for a budget k and build the program.

    A single-topology :func:`plan_batch` — ``strategy="soar"`` runs the
    same batched device engine (historically this path used the serial
    host solver and silently ignored engine options; it now delegates, so
    ``options=EngineOptions(...)`` applies and the masks are identical to
    a batch of one). Returns a :class:`TenantPlan`; ``blue, program =
    plan(...)`` still unpacks."""
    return plan_batch([topo], k, [avail], strategy=strategy,
                      options=options, **engine_kw)[0]


def plan_batch(topos: list[ClusterTopology], k: int,
               avails: list[np.ndarray | None] | None = None,
               strategy: str = "soar", *,
               options: EngineOptions | None = None, **engine_kw):
    """Batched planning: place B scenarios/workloads in one engine solve.

    For ``strategy="soar"`` all instances run through
    :func:`repro.engine.solve_batch` — the fully device-resident solve
    (fused level-fold gather + on-device color), so only the blue masks
    and costs the program builder needs ever leave the accelerator, and
    same-shape scenario fleets amortize to a single compiled executable
    (ragged fleets bucket onto few, see ``build_forest``). Engine behavior
    comes from ``options=EngineOptions(...)`` — the only spelling; the
    PR-4 legacy-kwargs shim is gone (stray kwargs raise ``TypeError``
    with the migration at this boundary).
    Other strategies fall back to the serial per-instance baselines.
    Returns ``[TenantPlan]`` in input order (each unpacks as the
    historical ``(blue, program)`` pair).
    """
    if not topos:
        return []
    avails = [None] * len(topos) if avails is None else list(avails)
    if len(avails) != len(topos):
        raise ValueError(f"{len(avails)} avail masks for {len(topos)} "
                         f"topologies — plan_batch pairs them positionally")
    # fault-domain plumbing: switches with a failed aggregation plane
    # (topo.blocked) leave the candidate set on every strategy path
    avails = [tp.candidates(av) for tp, av in zip(topos, avails, strict=True)]
    if strategy == "soar":
        opts = resolve_options(options, engine_kw, "plan_batch")
        if not opts.color:
            raise ValueError("plan_batch builds programs from blue masks; "
                             "the costs-only mode (color=False) is not "
                             "usable here — call repro.engine.solve_batch "
                             "directly")
        from ..engine import solve_batch
        res = solve_batch([tp.tree for tp in topos],
                          [tp.load for tp in topos], k, avails, options=opts)
        blues = [res.blue_of(b) for b in range(len(topos))]
    elif options is not None or engine_kw:
        named = sorted(engine_kw) if engine_kw else "options="
        raise ValueError(
            f"engine options {named} only apply to "
            f"strategy='soar', not {strategy!r}")
    else:
        fn = baselines.STRATEGIES[strategy]
        blues = [fn(tp.tree, tp.load, k, avail=av)
                 for tp, av in zip(topos, avails, strict=True)]
    out = []
    for tp, blue in zip(topos, blues, strict=True):
        prog = build_program(tp, blue)
        out.append(TenantPlan(blue, prog, prog.utilization))
    return out


def plan_congestion(topo: ClusterTopology, k: int,
                    loads: list[np.ndarray] | None = None,
                    count: int | None = None,
                    avails: list[np.ndarray | None] | np.ndarray | None = None,
                    **driver_kw):
    """Congestion-aware multi-tenant planning on one shared cluster tree.

    Runs the repeated-solve penalty driver
    (:func:`repro.engine.solve_congestion`) for T tenants sharing
    ``topo.tree`` — minimizing the *max-link* congestion across tenants
    instead of each tenant's utilization in isolation — then compiles one
    :class:`ReduceProgram` per tenant from the final masks. ``loads`` is
    one per-tenant load vector (or pass ``count`` to admit that many
    copies of ``topo.load`` — the orchestrator's admission shape);
    ``avails`` is a shared mask or a per-tenant list. Driver keyword
    arguments (``max_rounds``, ``alpha``, ``capacity``, ``residual`` —
    the hard in-loop admission ledger, validated here — ``device_loop``,
    ``options=EngineOptions(...)``, …) pass through. Returns a
    :class:`CongestionPlan` — per-tenant :class:`TenantPlan`\\ s in tenant
    order plus the driver's congestion diagnostics (baseline vs achieved
    max/mean, rounds, history, device↔host traffic); unpacks as the
    historical ``(planned, result)`` pair.
    """
    if (loads is None) == (count is None):
        raise ValueError("pass exactly one of loads / count")
    if loads is None:
        loads = [topo.load] * count
    # boundary validation (parity with plan_batch): a per-tenant avail list
    # must pair positionally, and a malformed capacity vector fails here,
    # not deep inside the engine
    if avails is not None and not isinstance(avails, np.ndarray):
        avails = list(avails)
        if len(avails) != len(loads):
            raise ValueError(
                f"{len(avails)} avail masks for {len(loads)} tenants — "
                "plan_congestion pairs them positionally")
    if driver_kw.get("capacity") is not None:
        driver_kw["capacity"] = _check_capacity(
            driver_kw["capacity"], topo.tree.n, "plan_congestion")
        if topo.cap_scale is not None:
            # partial-capacity degradation shrinks the capacity snapshot
            # the engine's crowding term prices against: a switch at half
            # its aggregation plane crowds twice as fast
            driver_kw["capacity"] = (driver_kw["capacity"]
                                     * np.clip(topo.cap_scale, 0.0, 1.0))
    if driver_kw.get("residual") is not None:
        driver_kw["residual"] = _check_residual(
            driver_kw["residual"], topo.tree.n, "plan_congestion")
    if topo.blocked is not None or topo.cap_scale is not None:
        # blocked and zero-capacity switches leave Lambda for every tenant
        if avails is None or isinstance(avails, np.ndarray):
            avails = topo.candidates(avails)
        else:
            avails = [topo.candidates(a) for a in avails]
    from ..engine import solve_congestion
    res = solve_congestion(topo.tree, loads, k, avail=avails, **driver_kw)
    plans = []
    for L, blue in zip(loads, res.blue, strict=True):
        tenant_topo = dataclasses.replace(topo, load=np.asarray(L, np.int64))
        prog = build_program(tenant_topo, blue)
        plans.append(TenantPlan(blue, prog, prog.utilization))
    return CongestionPlan(plans, res)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """:func:`plan_fleet`'s result: per-tenant plans + fleet diagnostics.

    ``plans`` is a list of :class:`TenantPlan` in tenant order (each
    tenant's blue mask and program live on its *own* tree — look up the
    tree with ``tree_of``); ``result`` is the driver's
    ``CongestionResult`` with per-link arrays in the fleet's global
    link-id space (tree segments first, shared-core links last).
    Unpacks as the ``(planned, result)`` pair like
    :class:`CongestionPlan`."""

    plans: list
    result: object                 # repro.engine.CongestionResult
    tree_of: np.ndarray            # (T,) tenant -> tree index

    def __iter__(self):
        return iter((self.plans, self.result))

    @property
    def max_congestion(self) -> float:
        return self.result.max_congestion

    @property
    def improvement(self) -> float:
        return self.result.improvement

    @property
    def core_congestion(self):
        return self.result.core_congestion


def plan_fleet(fleet: Fleet, k: int,
               loads: list[np.ndarray] | None = None,
               tree_of: list[int] | None = None,
               counts: list[int] | None = None,
               avails: list[np.ndarray | None] | None = None,
               **driver_kw) -> FleetPlan:
    """Congestion-coupled planning across a multi-tree fleet.

    T tenants spread over the fleet's N aggregation trees, solved
    *jointly* by :func:`repro.engine.solve_fleet`: every penalty round
    profiles the union of tree-local links and the fleet's shared-core
    links, so tenants on different trees trade placements through the
    links they share — two independent :func:`plan_congestion` calls
    cannot see that coupling. Tenant assignment comes either from
    ``counts`` (per-tree tenant counts; tenant loads default to each
    tree's ``topo.load`` — the admission shape) or from explicit
    ``loads`` + ``tree_of`` (one load vector per tenant, shaped for its
    own tree). ``avails`` is an optional per-tenant mask list; each
    tree's fault domains (``topo.blocked``) are subtracted for its own
    tenants. ``capacity`` / ``residual`` in ``driver_kw`` are per-*tree*
    lists of capacity vectors / hard-admission ledgers, validated here at
    the call boundary. Compiles one
    :class:`ReduceProgram` per tenant on its own tree and returns a
    :class:`FleetPlan`.

    For an N=1 fleet with no core links this is exactly
    :func:`plan_congestion` on the single topology — same masks, same
    costs, same round history (the engine path is shared, not parallel).
    """
    if not isinstance(fleet, Fleet):
        raise TypeError("plan_fleet needs a Fleet; wrap a single topology "
                        "with Fleet.single(topo)")
    N = fleet.n_trees
    if (loads is None) == (counts is None):
        raise ValueError("pass exactly one of loads / counts")
    if counts is not None:
        if tree_of is not None:
            raise ValueError("tree_of is derived from counts — pass it "
                             "only with explicit loads")
        counts = [int(c) for c in counts]
        if len(counts) != N or any(c < 1 for c in counts):
            raise ValueError(f"counts must give >=1 tenants for each of "
                             f"the {N} trees, got {counts}")
        tree_of = [g for g, c in enumerate(counts) for _ in range(c)]
        loads = [fleet.topos[g].load for g in tree_of]
    else:
        if tree_of is None:
            raise ValueError("explicit loads need tree_of (one tree index "
                             "per tenant)")
        tree_of = [int(g) for g in tree_of]
        loads = list(loads)
        if len(tree_of) != len(loads):
            raise ValueError(f"{len(tree_of)} tree indices for "
                             f"{len(loads)} loads")
    T = len(loads)
    tid = np.asarray(tree_of, np.int32)
    if T and (tid.min() < 0 or tid.max() >= N):
        raise ValueError(f"tree_of entries must be in [0, {N})")
    if avails is not None:
        avails = list(avails)
        if len(avails) != T:
            raise ValueError(f"{len(avails)} avail masks for {T} tenants — "
                             "plan_fleet pairs them positionally")
    else:
        avails = [None] * T
    # per-tree fault domains + mask validation at the boundary
    avails = [fleet.topos[g].candidates(av)
              for g, av in zip(tree_of, avails)]
    if driver_kw.get("capacity") is not None:
        caps = list(driver_kw["capacity"])
        if len(caps) != N:
            raise ValueError(f"{len(caps)} capacity vectors for {N} trees "
                             "— plan_fleet takes one per tree")
        driver_kw["capacity"] = [
            _check_capacity(c, fleet.topos[g].tree.n, "plan_fleet")
            * (np.clip(fleet.topos[g].cap_scale, 0.0, 1.0)
               if fleet.topos[g].cap_scale is not None else 1.0)
            for g, c in enumerate(caps)]
    if driver_kw.get("residual") is not None:
        resid = list(driver_kw["residual"])
        if len(resid) != N:
            raise ValueError(f"{len(resid)} residual ledgers for {N} trees "
                             "— plan_fleet takes one per tree")
        driver_kw["residual"] = [
            _check_residual(rg, fleet.topos[g].tree.n, "plan_fleet")
            for g, rg in enumerate(resid)]
    from ..engine import solve_fleet
    res = solve_fleet([tp.tree for tp in fleet.topos], loads, tid, k,
                      avails,
                      core_rho=fleet.core_rho if fleet.n_core else None,
                      core_path=fleet.core_path if fleet.n_core else None,
                      **driver_kw)
    plans = []
    for t, (L, g) in enumerate(zip(loads, tree_of, strict=True)):
        tp = fleet.topos[g]
        blue = res.blue[t, : tp.tree.n]
        tenant_topo = dataclasses.replace(tp, load=np.asarray(L, np.int64))
        prog = build_program(tenant_topo, blue)
        plans.append(TenantPlan(blue, prog, prog.utilization))
    return FleetPlan(plans, res, tid)
