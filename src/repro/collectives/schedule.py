"""SOAR placement -> static reduction program (the collective schedule).

Builds, for a cluster tree + blue placement, the exact message-passing
program a shard_map executor runs: which device sends which buffer slots to
whom in each round, and where partial sums are materialized. All counts are
static (topology, loads and coloring are known), so the program is a plain
Python object baked into the jitted collective.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.reduce import messages_up, phi
from ..core import baselines
from ..engine.options import EngineOptions, resolve_options
from .topology import ClusterTopology


@dataclasses.dataclass
class PermuteRound:
    perm: list                      # [(src_dev, dst_dev)]
    slab: int                       # slots sent per pair
    recv_offset: np.ndarray         # (n_dev,) slot offset at receiver
    recv_count: np.ndarray          # (n_dev,) valid incoming slots


@dataclasses.dataclass
class CompressOp:
    flag: np.ndarray                # (n_dev,) bool: device compresses now
    width: np.ndarray               # (n_dev,) slots to sum into slot 0


@dataclasses.dataclass
class ReduceProgram:
    n_dev: int
    n_slots: int
    ops: list                       # PermuteRound | CompressOp
    root_home: int
    root_count: int
    utilization: float              # phi of the underlying placement
    total_network_messages: int     # logical messages (== sum msgs_up)


def build_program(topo: ClusterTopology, blue: np.ndarray) -> ReduceProgram:
    t = topo.tree
    load = topo.load
    if topo.blocked is not None and np.any(np.asarray(blue, bool)
                                           & topo.blocked):
        raise ValueError("blue placement aggregates at a failed switch")
    if any(load[v] > 0 and len(t.children[v]) > 0 for v in range(t.n)):
        raise ValueError("executor supports leaf-only loads")
    n_dev = topo.n_devices
    msgs = messages_up(t, load, blue)

    # homes: leaf -> its device; internal -> home of first nonempty child
    home = np.full(t.n, -1, np.int64)
    for dev, leaf in enumerate(topo.device_leaf):
        if leaf >= 0:
            home[leaf] = dev
    for v in t.topo[::-1]:
        if home[v] < 0:
            for c in t.children[v]:
                if home[c] >= 0:
                    home[v] = home[c]
                    break
    # out-counts after aggregation decisions
    out = msgs  # msgs_up already encodes red forward / blue collapse

    ops: list = []
    n_slots = 1
    # process internal switches level by level (deepest parents first)
    order = [v for v in t.topo[::-1] if t.children[v]]
    level_of = {v: int(t.depth[v]) for v in range(t.n)}
    for depth in sorted({level_of[v] for v in order}, reverse=True):
        parents = [v for v in order if level_of[v] == depth]
        maxc = max(len(t.children[v]) for v in parents)
        for ci in range(1, maxc):   # child 0 lives at the parent's home
            perm, roff, rcnt = [], np.zeros(n_dev, np.int64), np.zeros(n_dev, np.int64)
            slab = 0
            for p in parents:
                kids = [c for c in t.children[p] if home[c] >= 0]
                if ci >= len(kids):
                    continue
                c = kids[ci]
                cnt = int(out[c])
                if cnt == 0 or home[c] == home[p]:
                    continue
                off = int(load[p]) + sum(int(out[kids[j]]) for j in range(ci))
                perm.append((int(home[c]), int(home[p])))
                roff[home[p]] = off
                rcnt[home[p]] = cnt
                slab = max(slab, cnt)
                n_slots = max(n_slots, off + cnt)
            if perm:
                ops.append(PermuteRound(perm, slab, roff, rcnt))
        # compress at blue parents of this level
        flag = np.zeros(n_dev, bool)
        width = np.ones(n_dev, np.int64)
        any_comp = False
        for p in parents:
            if blue[p] and home[p] >= 0:
                kids = [c for c in t.children[p] if home[c] >= 0]
                total = int(load[p]) + sum(int(out[c]) for c in kids)
                if total > 1:
                    flag[home[p]] = True
                    width[home[p]] = total
                    n_slots = max(n_slots, total)
                    any_comp = True
        if any_comp:
            ops.append(CompressOp(flag, width))

    r = t.root
    return ReduceProgram(
        n_dev=n_dev,
        n_slots=n_slots,
        ops=ops,
        root_home=int(home[r]),
        root_count=int(out[r]),
        utilization=phi(t, load, blue),
        total_network_messages=int(msgs.sum()),
    )


@dataclasses.dataclass(frozen=True)
class TenantPlan:
    """One planned tenant: the blue mask, its compiled program, its cost.

    ``cost`` is the placement's utilization (phi on the original rho, the
    same number :class:`ReduceProgram` carries). Iterable-unpacking keeps
    the historical ``blue, program = plan(...)`` spelling working."""

    blue: np.ndarray
    program: ReduceProgram
    cost: float

    def __iter__(self):
        return iter((self.blue, self.program))


@dataclasses.dataclass(frozen=True)
class CongestionPlan:
    """:func:`plan_congestion`'s result: per-tenant plans + diagnostics.

    ``plans`` is a list of :class:`TenantPlan` in tenant order; ``result``
    the driver's ``CongestionResult`` (baseline vs achieved congestion,
    rounds, history, transfer accounting). Unpacks as the historical
    ``planned, res = plan_congestion(...)`` pair."""

    plans: list
    result: object                 # repro.engine.CongestionResult

    def __iter__(self):
        return iter((self.plans, self.result))

    @property
    def max_congestion(self) -> float:
        return self.result.max_congestion

    @property
    def improvement(self) -> float:
        return self.result.improvement


def plan(topo: ClusterTopology, k: int, avail: np.ndarray | None = None,
         strategy: str = "soar", *, options: EngineOptions | None = None,
         **engine_kw) -> TenantPlan:
    """Choose the blue set for a budget k and build the program.

    A single-topology :func:`plan_batch` — ``strategy="soar"`` runs the
    same batched device engine (historically this path used the serial
    host solver and silently ignored engine options; it now delegates, so
    ``options=EngineOptions(...)`` applies and the masks are identical to
    a batch of one). Returns a :class:`TenantPlan`; ``blue, program =
    plan(...)`` still unpacks."""
    return plan_batch([topo], k, [avail], strategy=strategy,
                      options=options, **engine_kw)[0]


def plan_batch(topos: list[ClusterTopology], k: int,
               avails: list[np.ndarray | None] | None = None,
               strategy: str = "soar", *,
               options: EngineOptions | None = None, **engine_kw):
    """Batched planning: place B scenarios/workloads in one engine solve.

    For ``strategy="soar"`` all instances run through
    :func:`repro.engine.solve_batch` — the fully device-resident solve
    (fused level-fold gather + on-device color), so only the blue masks
    and costs the program builder needs ever leave the accelerator, and
    same-shape scenario fleets amortize to a single compiled executable
    (ragged fleets bucket onto few, see ``build_forest``). Engine behavior
    comes from ``options=EngineOptions(...)`` (legacy engine keyword
    arguments still work for one release, with a ``DeprecationWarning``).
    Other strategies fall back to the serial per-instance baselines.
    Returns ``[TenantPlan]`` in input order (each unpacks as the
    historical ``(blue, program)`` pair).
    """
    if not topos:
        return []
    avails = [None] * len(topos) if avails is None else list(avails)
    if len(avails) != len(topos):
        raise ValueError(f"{len(avails)} avail masks for {len(topos)} "
                         f"topologies — plan_batch pairs them positionally")
    # fault-domain plumbing: switches with a failed aggregation plane
    # (topo.blocked) leave the candidate set on every strategy path
    avails = [tp.candidates(av) for tp, av in zip(topos, avails, strict=True)]
    if strategy == "soar":
        opts = resolve_options(options, engine_kw, "plan_batch")
        if not opts.color:
            raise ValueError("plan_batch builds programs from blue masks; "
                             "the costs-only mode (color=False) is not "
                             "usable here — call repro.engine.solve_batch "
                             "directly")
        from ..engine import solve_batch
        res = solve_batch([tp.tree for tp in topos],
                          [tp.load for tp in topos], k, avails, options=opts)
        blues = [res.blue_of(b) for b in range(len(topos))]
    elif options is not None or engine_kw:
        named = sorted(engine_kw) if engine_kw else "options="
        raise ValueError(
            f"engine options {named} only apply to "
            f"strategy='soar', not {strategy!r}")
    else:
        fn = baselines.STRATEGIES[strategy]
        blues = [fn(tp.tree, tp.load, k, avail=av)
                 for tp, av in zip(topos, avails, strict=True)]
    out = []
    for tp, blue in zip(topos, blues, strict=True):
        prog = build_program(tp, blue)
        out.append(TenantPlan(blue, prog, prog.utilization))
    return out


def plan_congestion(topo: ClusterTopology, k: int,
                    loads: list[np.ndarray] | None = None,
                    count: int | None = None,
                    avails: list[np.ndarray | None] | np.ndarray | None = None,
                    **driver_kw):
    """Congestion-aware multi-tenant planning on one shared cluster tree.

    Runs the repeated-solve penalty driver
    (:func:`repro.engine.solve_congestion`) for T tenants sharing
    ``topo.tree`` — minimizing the *max-link* congestion across tenants
    instead of each tenant's utilization in isolation — then compiles one
    :class:`ReduceProgram` per tenant from the final masks. ``loads`` is
    one per-tenant load vector (or pass ``count`` to admit that many
    copies of ``topo.load`` — the orchestrator's admission shape);
    ``avails`` is a shared mask or a per-tenant list. Driver keyword
    arguments (``max_rounds``, ``alpha``, ``capacity``, ``device_loop``,
    ``options=EngineOptions(...)``, …) pass through. Returns a
    :class:`CongestionPlan` — per-tenant :class:`TenantPlan`\\ s in tenant
    order plus the driver's congestion diagnostics (baseline vs achieved
    max/mean, rounds, history, device↔host traffic); unpacks as the
    historical ``(planned, result)`` pair.
    """
    if (loads is None) == (count is None):
        raise ValueError("pass exactly one of loads / count")
    if loads is None:
        loads = [topo.load] * count
    if topo.blocked is not None:
        # blocked switches leave Lambda for every tenant
        if avails is None or isinstance(avails, np.ndarray):
            avails = topo.candidates(avails)
        else:
            avails = [topo.candidates(a) for a in avails]
    from ..engine import solve_congestion
    res = solve_congestion(topo.tree, loads, k, avail=avails, **driver_kw)
    plans = []
    for L, blue in zip(loads, res.blue, strict=True):
        tenant_topo = dataclasses.replace(topo, load=np.asarray(L, np.int64))
        prog = build_program(tenant_topo, blue)
        plans.append(TenantPlan(blue, prog, prog.utilization))
    return CongestionPlan(plans, res)
