"""Attention variants: GQA (opt. qk-norm / sliding window) and MLA.

All functions are pure; KV caches are explicit pytrees. Shapes:
  x:      (B, T, d)
  cache:  gqa: {"k","v": (B, S, Hkv, hd)};  mla: {"ckv": (B, S, r), "kr": (B, S, rr)}
Decode steps take the current position ``pos`` (int32 scalar) and write into
the fixed-size cache with dynamic_update_slice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import cs
from .config import ModelConfig
from .layers import apply_rope, dense_init, dtype_of, rms_head_norm, rope_tables

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared: masked softmax attention core (pure jnp; Pallas kernel is the TPU path)
# ---------------------------------------------------------------------------

def sdpa(q, k, v, mask, scale):
    """q: (B,T,H,Dq) k: (B,S,Hkv,Dq) v: (B,S,Hkv,Dv); GQA by head grouping."""
    B, T, H, Dq = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, Dq)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", w, v)
    return out.reshape(B, T, H, -1)


def causal_mask(T: int, S: int, window: int = 0, offset: int = 0):
    """(T, S) boolean mask; q position i attends to keys <= i (+window)."""
    qpos = jnp.arange(T)[:, None] + offset
    kpos = jnp.arange(S)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


# Blocked attention activates for sequences at least this long (and the
# block size). 2048 divides every assigned shape (4k/32k/512k).
SDPA_BLOCK = 2048


def sdpa_blocked(q, k, v, scale, causal=True, window=0, block=SDPA_BLOCK):
    """Online-softmax blocked attention (flash-attention dataflow in jnp).

    Never materializes the (T, S) score matrix: a static double loop over
    (query block, key block) tiles keeps live intermediates at
    (B, H, block, block), with causal / sliding-window tiles skipped at
    trace time. This is the jnp analogue of kernels/flash_attention (the
    Pallas TPU path); identical semantics to ``sdpa`` (tested).
    """
    B, T, H, Dq = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    assert T % block == 0 and S % block == 0
    nq, nk = T // block, S // block
    outs = []
    for i in range(nq):
        qi = q[:, i * block:(i + 1) * block].reshape(B, block, Hkv, G, Dq)
        q_lo = i * block
        # key-block range needed by this query block (static skipping)
        # causal skipping assumes q/k positions aligned, which holds only
        # for the square self-attention case (T == S)
        j_hi = i + 1 if (causal and T == S) else nk
        j_lo = 0
        if window and causal and T == S:
            j_lo = max(0, (q_lo - window) // block)
        m = jnp.full((B, Hkv, G, block), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, Hkv, G, block), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, block, Dv), jnp.float32)
        for j in range(j_lo, j_hi):
            kj = k[:, j * block:(j + 1) * block]
            vj = v[:, j * block:(j + 1) * block]
            s = jnp.einsum("bthgd,bshd->bhgts", qi, kj).astype(
                jnp.float32) * scale
            if causal and T == S:
                if window:                          # every tile in the band
                    msk = causal_mask(block, block, window,
                                      offset=(i - j) * block)
                    s = jnp.where(msk[None, None, None], s, NEG_INF)
                elif i == j:                        # diagonal tile
                    msk = causal_mask(block, block)
                    s = jnp.where(msk[None, None, None], s, NEG_INF)
                # fully-inside tiles need no mask
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l = l * alpha + pexp.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgts,bshd->bhgtd", pexp.astype(vj.dtype), vj)
            m = m_new
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(B, block, H, Dv))
    return jnp.concatenate(outs, axis=1)


def _pick_block(T: int, S: int, window: int = 0) -> int | None:
    """Tile size for blocked attention, or None to use plain sdpa.

    Sliding-window layers tile at the window size (the band then spans
    exactly two tiles per query block instead of mostly-masked big tiles).
    """
    block = min(SDPA_BLOCK, window) if window else SDPA_BLOCK
    if T >= block >= 256 and T % block == 0 and S % block == 0:
        return block
    return None


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {
        "w_q": dense_init(ks[0], (d, H * hd), dt),
        "w_k": dense_init(ks[1], (d, Hkv * hd), dt),
        "w_v": dense_init(ks[2], (d, Hkv * hd), dt),
        "w_o": dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    B, T, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["w_q"]).reshape(B, T, H, hd)
    k = (x @ p["w_k"]).reshape(B, T, Hkv, hd)
    v = (x @ p["w_v"]).reshape(B, T, Hkv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)  # (T, hd/2)
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, causal: bool = True, window: int = 0):
    """Full-sequence attention (train / prefill). Returns (out, {"k","v"})."""
    B, T, _ = x.shape
    positions = jnp.arange(T)
    q, k, v = _qkv(p, x, cfg, positions)
    q = cs(q, "batch", "seq", "heads", None)
    k = cs(k, "batch", "seq", "kv_heads", None)
    v = cs(v, "batch", "seq", "kv_heads", None)
    scale = 1.0 / jnp.sqrt(cfg.hd).astype(jnp.float32)
    block = _pick_block(T, T, window)
    if block:
        out = sdpa_blocked(q, k, v, scale, causal=causal, window=window,
                           block=block)
    else:
        if causal:
            mask = causal_mask(T, T, window)[None]
        else:
            mask = jnp.ones((1, T, T), bool)
        out = sdpa(q, k, v, mask, scale)
    out = out.reshape(B, T, -1) @ p["w_o"]
    return cs(out, "batch", "seq", "embed"), {"k": k, "v": v}


def gqa_decode(p, x, cache, pos, cfg: ModelConfig, window: int = 0):
    """Single-token decode. x: (B, 1, d); cache k/v: (B, S, Hkv, hd)."""
    B = x.shape[0]
    S = cache["k"].shape[1]
    q, k, v = _qkv(p, x, cfg, jnp.full((1,), pos))
    if window and window < S + 1:
        # ring buffer: once pos >= window every slot holds one of the last
        # `window` tokens (each rope'd at its absolute position on write).
        slot = jnp.mod(pos, cache["k"].shape[1])
        k_all = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        mask = (jnp.arange(k_all.shape[1]) <= pos)[None, None, :]
    else:
        k_all = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        mask = (jnp.arange(k_all.shape[1]) <= pos)[None, None, :]
    out = sdpa(q, k_all, v_all, mask,
               1.0 / jnp.sqrt(cfg.hd).astype(jnp.float32))
    out = out.reshape(B, 1, -1) @ p["w_o"]
    return out, {"k": k_all, "v": v_all}


def gqa_cache_spec(cfg: ModelConfig, batch: int, seq: int, window: int = 0):
    S = min(seq, window) if window else seq
    shape = (batch, S, cfg.n_kv_heads, cfg.hd)
    z = jnp.zeros  # used under eval_shape for dry-run
    return {"k": z(shape, dtype_of(cfg)), "v": z(shape, dtype_of(cfg))}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3): latent-compressed KV
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    nd, rd, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    p = {}
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], (d, cfg.q_lora_rank), dt)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dt)
        p["w_uq"] = dense_init(ks[1], (cfg.q_lora_rank, H * (nd + rd)), dt)
    else:
        p["w_q"] = dense_init(ks[1], (d, H * (nd + rd)), dt)
    p["w_dkv"] = dense_init(ks[2], (d, r + rd), dt)  # latent + shared k_rope
    p["kv_norm"] = jnp.ones((r,), dt)
    p["w_ukv"] = dense_init(ks[3], (r, H * (nd + vd)), dt)
    p["w_o"] = dense_init(ks[4], (H * vd, d), dt)
    return p


def _mla_q(p, x, cfg: ModelConfig, positions):
    B, T, _ = x.shape
    H, nd, rd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        ql = rms_head_norm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
        q = (ql @ p["w_uq"]).reshape(B, T, H, nd + rd)
    else:
        q = (x @ p["w_q"]).reshape(B, T, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_tables(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None, :, None, :], sin[None, :, None, :])
    return q_nope, q_rope


def _mla_latent(p, x, cfg: ModelConfig, positions):
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv_kr = x @ p["w_dkv"]
    ckv = rms_head_norm(p["kv_norm"], ckv_kr[..., :r], cfg.norm_eps)
    cos, sin = rope_tables(positions, rd, cfg.rope_theta)
    kr = apply_rope(ckv_kr[..., r:], cos[None], sin[None])  # shared head
    return ckv, kr


def mla_forward(p, x, cfg: ModelConfig, causal: bool = True):
    """Materialized-KV full-sequence MLA. Returns (out, {"ckv","kr"})."""
    B, T, _ = x.shape
    H, nd, rd, vd, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    positions = jnp.arange(T)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv, kr = _mla_latent(p, x, cfg, positions)
    kv = (ckv @ p["w_ukv"]).reshape(B, T, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    scale = 1.0 / jnp.sqrt(nd + rd).astype(jnp.float32)
    if _pick_block(T, T):
        # fold the shared rope head into per-head keys: the two-einsum sum
        # equals one dot over the concatenated (nope | rope) feature dim
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, T, H, rd))], -1)
        out = sdpa_blocked(q_full, k_full, v, scale, causal=causal)
        out = out.reshape(B, T, H * vd)
    else:
        mask = causal_mask(T, T) if causal else jnp.ones((T, T), bool)
        logits = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope) +
                  jnp.einsum("bthd,bsd->bhts", q_rope, kr)).astype(jnp.float32)
        logits = jnp.where(mask[None, None], logits * scale, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhts,bshd->bthd", w, v).reshape(B, T, H * vd)
    return cs(out @ p["w_o"], "batch", "seq", "embed"), {"ckv": ckv, "kr": kr}


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    """Absorbed (latent-space) single-token decode: O(S·r) per head pair."""
    B = x.shape[0]
    H, nd, rd, vd, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    positions = jnp.full((1,), pos)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)      # (B,1,H,nd),(B,1,H,rd)
    ckv_t, kr_t = _mla_latent(p, x, cfg, positions)    # (B,1,r),(B,1,rd)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_t, (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_t, (0, pos, 0))
    w_uk = p["w_ukv"].reshape(r, H, nd + vd)[..., :nd]   # (r, H, nd)
    w_uv = p["w_ukv"].reshape(r, H, nd + vd)[..., nd:]   # (r, H, vd)
    if cfg.decode_absorb:
        # absorb W_uk into q: score space becomes the latent space
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk)      # (B,1,H,r)
        logits = (jnp.einsum("bthr,bsr->bhts", q_lat, ckv) +
                  jnp.einsum("bthd,bsd->bhts", q_rope, kr))
    else:
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, w_uk)
        logits = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope) +
                  jnp.einsum("bthd,bsd->bhts", q_rope, kr))
    scale = 1.0 / jnp.sqrt(nd + rd).astype(jnp.float32)
    mask = (jnp.arange(ckv.shape[1]) <= pos)[None, None, None, :]
    logits = jnp.where(mask, logits.astype(jnp.float32) * scale, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    if cfg.decode_absorb:
        ctx_lat = jnp.einsum("bhts,bsr->bthr", w, ckv)           # (B,1,H,r)
        out = jnp.einsum("bthr,rhd->bthd", ctx_lat, w_uv)
    else:
        v = jnp.einsum("bsr,rhd->bshd", ckv, w_uv)
        out = jnp.einsum("bhts,bshd->bthd", w, v)
    out = out.reshape(B, 1, H * vd) @ p["w_o"]
    return out, {"ckv": ckv, "kr": kr}


def mla_cache_spec(cfg: ModelConfig, batch: int, seq: int):
    dt = dtype_of(cfg)
    return {
        "ckv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dt),
        "kr": jnp.zeros((batch, seq, cfg.qk_rope_dim), dt),
    }
