"""Recurrent mixers: xLSTM's mLSTM / sLSTM and a Mamba-style selective SSM.

Design notes (DESIGN.md §Hardware adaptation):
  * mLSTM uses the *chunkwise-parallel* form — intra-chunk terms are dense
    (MXU-friendly) and the cross-chunk recurrence is a lax.scan over chunk
    summaries, giving O(T·c) instead of O(T^2) work: this is what makes the
    long_500k shape tractable.
  * sLSTM and Mamba keep a faithful sequential lax.scan (their recurrences
    are input-dependent in a way that defeats simple chunking); decode is a
    single step either way, and the scan lowers to a while-loop whose body
    is compiled once.
  * Gate activations are sigmoid-stabilized variants (the official exp-gating
    with max-stabilizer is replaced by sigmoid forget / sigmoid input gates);
    this keeps state bounded without the m_t bookkeeping.

All states are fp32; inputs/outputs follow cfg.dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import cs
from .config import ModelConfig
from .layers import dense_init, dtype_of


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM): chunkwise-parallel linear-attention style
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    return {
        "w_q": dense_init(ks[0], (d, H * hd), dt),
        "w_k": dense_init(ks[1], (d, H * hd), dt),
        "w_v": dense_init(ks[2], (d, H * hd), dt),
        "w_if": dense_init(ks[3], (d, 2 * H), dt),   # input & forget gates
        "w_o": dense_init(ks[4], (H * hd, d), dt),
        "out_gate": dense_init(ks[5], (d, H * hd), dt),
    }


def mlstm_state(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
    }


def _mlstm_chunk(carry, inp, hd):
    """One chunk: q,k,v: (B,c,H,hd); i,f: (B,c,H) in (0,1)."""
    C, n = carry                      # (B,H,hd,hd), (B,H,hd)
    q, k, v, ig, fg = inp
    B, c, H, _ = q.shape
    logf = jnp.log(fg + 1e-8)                       # (B,c,H)
    cumf = jnp.cumsum(logf, axis=1)                 # prod f_1..t
    # inter-chunk: state decayed to step t
    decay_to_t = jnp.exp(cumf)                      # (B,c,H)
    h_inter = jnp.einsum("bhde,bche->bchd", C, q) * decay_to_t[..., None]
    n_inter = jnp.einsum("bhd,bchd->bch", n, q) * decay_to_t
    # intra-chunk: D[t,s] = exp(cumf_t - cumf_s) * i_s for s <= t
    dmat = cumf[:, :, None, :] - cumf[:, None, :, :]          # (B,t,s,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    dmat = jnp.where(tri[None, :, :, None], jnp.exp(dmat), 0.0)
    dmat = dmat * ig[:, None, :, :]                            # * i_s
    scores = jnp.einsum("bthd,bshd->btsh", q, k).astype(jnp.float32)
    w = scores * dmat
    h_intra = jnp.einsum("btsh,bshd->bthd", w.astype(v.dtype), v)
    n_intra = jnp.einsum("btsh,bshd->bth", w, k.astype(jnp.float32))
    h = h_inter + h_intra
    norm = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)[..., None]
    h = h / norm
    # carry update
    decay_all = jnp.exp(cumf[:, -1])                           # (B,H)
    w_end = jnp.exp(cumf[:, -1:, :] - cumf) * ig               # (B,c,H)
    C_new = C * decay_all[..., None, None] + jnp.einsum(
        "bch,bchd,bche->bhde", w_end, v.astype(jnp.float32),
        k.astype(jnp.float32))
    n_new = n * decay_all[..., None] + jnp.einsum(
        "bch,bchd->bhd", w_end, k.astype(jnp.float32))
    return (C_new, n_new), h


def mlstm_forward(p, x, cfg: ModelConfig, state=None):
    """x: (B, T, d); T must be a multiple of chunk (padded by caller)."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    c = min(cfg.chunk_size, T)
    assert T % c == 0, "caller must pad to chunk multiple"
    q = (x @ p["w_q"]).reshape(B, T, H, hd) / jnp.sqrt(hd)
    k = (x @ p["w_k"]).reshape(B, T, H, hd) / jnp.sqrt(hd)
    v = (x @ p["w_v"]).reshape(B, T, H, hd)
    gates = jax.nn.sigmoid((x @ p["w_if"]).astype(jnp.float32))
    ig, fg = gates[..., :H], gates[..., H:]
    nchunks = T // c

    def to_chunks(a):
        return a.reshape(B, nchunks, c, *a.shape[2:]).swapaxes(0, 1)

    st = state or mlstm_state(cfg, B)
    carry = (st["C"], st["n"])
    (C_f, n_f), hs = jax.lax.scan(
        lambda cr, ch: _mlstm_chunk(cr, ch, hd), carry,
        tuple(map(to_chunks, (q, k, v, ig, fg))))
    h = hs.swapaxes(0, 1).reshape(B, T, H * hd).astype(x.dtype)
    h = h * jax.nn.sigmoid(x @ p["out_gate"])
    out = h @ p["w_o"]
    return cs(out, "batch", "seq", "embed"), {"C": C_f, "n": n_f}


def mlstm_decode(p, x, state, cfg: ModelConfig):
    """Single-step recurrent update. x: (B, 1, d)."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["w_q"]).reshape(B, H, hd).astype(jnp.float32) / jnp.sqrt(hd)
    k = (x @ p["w_k"]).reshape(B, H, hd).astype(jnp.float32) / jnp.sqrt(hd)
    v = (x @ p["w_v"]).reshape(B, H, hd).astype(jnp.float32)
    gates = jax.nn.sigmoid((x @ p["w_if"]).astype(jnp.float32)).reshape(B, 2 * H)
    ig, fg = gates[:, :H], gates[:, H:]
    C = state["C"] * fg[..., None, None] + \
        ig[..., None, None] * v[..., :, None] * k[..., None, :]
    n = state["n"] * fg[..., None] + ig[..., None] * k
    h = jnp.einsum("bhde,bhe->bhd", C, q)
    norm = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    h = (h / norm[..., None]).reshape(B, 1, H * hd).astype(x.dtype)
    h = h * jax.nn.sigmoid(x @ p["out_gate"])
    return h @ p["w_o"], {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with recurrent gates) — sequential scan
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    return {
        "w_in": dense_init(ks[0], (d, 4 * H * hd), dt),       # z, i, f, o
        "r": dense_init(ks[1], (H, hd, 4 * hd), dt, scale=0.5),  # block-diag recurrent
        "w_o": dense_init(ks[2], (H * hd, d), dt),
    }


def slstm_state(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z()}


def _slstm_step(p, carry, u, H, hd):
    c, n, h = carry                     # (B,H,hd) each
    rec = jnp.einsum("bhd,hde->bhe", h, p["r"].astype(jnp.float32))
    zi, ii, fi, oi = jnp.split(u.astype(jnp.float32) + rec, 4, axis=-1)
    z = jnp.tanh(zi)
    i = jax.nn.sigmoid(ii)
    f = jax.nn.sigmoid(fi)
    o = jax.nn.sigmoid(oi)
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h), h


def slstm_forward(p, x, cfg: ModelConfig, state=None):
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    u = (x @ p["w_in"]).reshape(B, T, H, 4 * hd)
    st = state or slstm_state(cfg, B)
    (c, n, h), hs = jax.lax.scan(
        lambda cr, ut: _slstm_step(p, cr, ut, H, hd),
        (st["c"], st["n"], st["h"]), u.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).reshape(B, T, H * hd).astype(x.dtype) @ p["w_o"]
    return cs(out, "batch", "seq", "embed"), {"c": c, "n": n, "h": h}


def slstm_decode(p, x, state, cfg: ModelConfig):
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    u = (x @ p["w_in"]).reshape(B, H, 4 * hd)
    (c, n, h), hh = _slstm_step(p, (state["c"], state["n"], state["h"]), u, H, hd)
    out = hh.reshape(B, 1, H * hd).astype(x.dtype) @ p["w_o"]
    return out, {"c": c, "n": n, "h": h}


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba's SSM heads) — sequential scan
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, d_out: int | None = None):
    d = cfg.d_model
    di = int(cfg.d_inner_mult * d)
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dt),          # u, z
        "w_bcdt": dense_init(ks[1], (di, 2 * N + 1), dt),    # B, C, dt
        "a_log": jnp.zeros((di, N), jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32) - 4.0,
        "w_out": dense_init(ks[2], (di, d_out or d), dt),
    }


def mamba_state(cfg: ModelConfig, batch: int):
    di = int(cfg.d_inner_mult * cfg.d_model)
    return {"s": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)}


def _mamba_step(p, s, u_t, z_t, N):
    """u_t, z_t: (B, di)."""
    uf = u_t.astype(jnp.float32)
    bcdt = (u_t @ p["w_bcdt"]).astype(jnp.float32)            # (B, 2N+1)
    Bv, Cv, dt_raw = bcdt[:, :N], bcdt[:, N : 2 * N], bcdt[:, -1:]
    delta = jax.nn.softplus(dt_raw + p["dt_bias"][None, :1])  # (B,1) scalar-ish
    A = -jnp.exp(p["a_log"])                                  # (di, N)
    decay = jnp.exp(delta[..., None] * A[None])               # (B, di, N)
    s = s * decay + (delta * uf)[..., None] * Bv[:, None, :]
    y = jnp.einsum("bdn,bn->bd", s, Cv) + p["d_skip"] * uf
    y = y * jax.nn.silu(z_t.astype(jnp.float32))
    return s, y


def mamba_forward_sequential(p, x, cfg: ModelConfig, state=None):
    """Reference per-timestep scan (the GPU-kernel-shaped formulation).

    Kept as the numerical oracle for the chunkwise path and as a fallback
    for sequence lengths that don't chunk; T sequential steps lower to a
    T-trip while loop — latency-bound on TPU (see EXPERIMENTS.md §Perf).
    """
    B, T, d = x.shape
    N = cfg.ssm_state
    uz = x @ p["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)
    st = state or mamba_state(cfg, B)

    def step(s, inp):
        u_t, z_t = inp
        s, y = _mamba_step(p, s, u_t, z_t, N)
        return s, y

    s_f, ys = jax.lax.scan(step, st["s"], (u.swapaxes(0, 1), z.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).astype(x.dtype) @ p["w_out"]
    return cs(y, "batch", "seq", "embed"), {"s": s_f}


def mamba_forward(p, x, cfg: ModelConfig, state=None):
    """Chunkwise-parallel selective scan (TPU-native adaptation).

    The recurrence s_t = decay_t * s_{t-1} + w_t is linear with a diagonal
    transition, so within a chunk of length c we run an exact
    associative_scan over (decay, w) pairs — log2(c) parallel elementwise
    steps instead of c sequential ones — and carry only the chunk-final
    state across chunks (a T/c-trip lax.scan). No decay-division trick, so
    it is numerically exact (combine is multiply-add in fp32).

    vs the sequential form on train_4k this cuts the lowered while-loop
    trip count 4096 -> 16 and turns the inner work into batched tensor ops
    (EXPERIMENTS.md §Perf, hymba cell).
    """
    B, T, d = x.shape
    N = cfg.ssm_state
    c = min(cfg.chunk_size, T)
    if T % c != 0 or T == 1:
        return mamba_forward_sequential(p, x, cfg, state)
    uz = x @ p["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)                        # (B,T,di)
    st = state or mamba_state(cfg, B)
    di = u.shape[-1]
    bcdt = (u @ p["w_bcdt"]).astype(jnp.float32)            # (B,T,2N+1)
    Bv, Cv = bcdt[..., :N], bcdt[..., N:2 * N]
    delta = jax.nn.softplus(bcdt[..., -1:] + p["dt_bias"][None, None, :1])
    A = -jnp.exp(p["a_log"])                                # (di,N)
    uf = u.astype(jnp.float32)
    nchunks = T // c

    def to_chunks(a):
        return a.reshape(B, nchunks, c, *a.shape[2:]).swapaxes(0, 1)

    def combine(left, right):
        dl, xl = left
        dr, xr = right
        return dl * dr, xr + dr * xl

    def chunk_body(s0, inp):
        u_c, delta_c, Bv_c, Cv_c = inp                      # (B,c,...)
        decay = jnp.exp(delta_c[..., None] * A[None, None])  # (B,c,di,N)
        w = (delta_c * u_c)[..., None] * Bv_c[:, :, None, :]
        dec_pfx, s_pfx = jax.lax.associative_scan(
            combine, (decay, w), axis=1)
        s_all = dec_pfx * s0[:, None] + s_pfx               # (B,c,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", s_all, Cv_c)
        return s_all[:, -1], y

    s_f, ys = jax.lax.scan(
        chunk_body, st["s"],
        tuple(map(to_chunks, (uf, delta, Bv, Cv))))
    y = ys.swapaxes(0, 1).reshape(B, T, di)
    y = y + p["d_skip"] * uf
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y.astype(x.dtype) @ p["w_out"]
    return cs(y, "batch", "seq", "embed"), {"s": s_f}


def mamba_decode(p, x, state, cfg: ModelConfig):
    B = x.shape[0]
    uz = x[:, 0, :] @ p["w_in"]
    u, z = jnp.split(uz, 2, axis=-1)
    s, y = _mamba_step(p, state["s"], u, z, cfg.ssm_state)
    out = y[:, None, :].astype(x.dtype) @ p["w_out"]
    return out, {"s": s}
