"""Family-dispatching model API + assigned input shapes.

Entry points used by launchers, tests, and the dry-run:
  init_fn(cfg)(key) -> params
  loss_fn(cfg)(params, batch) -> (loss, metrics)
  prefill_fn(cfg)(params, batch) -> (last_logits, caches)
  decode_fn(cfg)(params, caches, token, pos) -> (logits, caches)
  init_caches(cfg, batch, seq) -> zero caches
  input_specs(cfg, shape, mode) -> batch pytree (zeros; use jax.eval_shape /
      ShapeDtypeStruct conversion for allocation-free dry-runs)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig
from .layers import dtype_of


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 524k dense KV excluded (DESIGN.md)"
    return True, ""


def _is_encdec(cfg: ModelConfig) -> bool:
    return cfg.is_encoder_decoder


def init_fn(cfg: ModelConfig):
    mod = encdec if _is_encdec(cfg) else transformer
    return functools.partial(mod.init_params, cfg)


def loss_fn(cfg: ModelConfig):
    mod = encdec if _is_encdec(cfg) else transformer
    return lambda params, batch: mod.loss_fn(params, batch, cfg)


def prefill_fn(cfg: ModelConfig):
    mod = encdec if _is_encdec(cfg) else transformer
    return lambda params, batch: mod.prefill(params, batch, cfg)


def decode_fn(cfg: ModelConfig):
    mod = encdec if _is_encdec(cfg) else transformer
    return lambda params, caches, token, pos: mod.decode_step(
        params, caches, token, pos, cfg)


def init_caches(cfg: ModelConfig, batch: int, seq: int):
    if _is_encdec(cfg):
        return encdec.init_caches(cfg, batch, seq)
    return transformer.init_caches(cfg, batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mode: str | None = None):
    """Batch pytree of zeros for (cfg, shape); wrap in eval_shape for dry-run."""
    mode = mode or shape.kind
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg)
    if _is_encdec(cfg):
        T = max(8, S // cfg.target_ratio) if mode == "train" else 8
        T = min(T, encdec.WHISPER_MAX_TARGET)
        batch = {"frames": jnp.zeros((B, S, cfg.d_model), dt)}
        if mode == "train":
            batch["tokens"] = jnp.zeros((B, T), jnp.int32)
            batch["labels"] = jnp.zeros((B, T), jnp.int32)
        else:
            batch["tokens"] = jnp.zeros((B, T), jnp.int32)
        return batch
    if cfg.n_prefix_embeds and mode in ("train", "prefill"):
        P = min(cfg.n_prefix_embeds, S // 2)
        batch = {
            "prefix_embeds": jnp.zeros((B, P, cfg.d_model), dt),
            "tokens": jnp.zeros((B, S - P), jnp.int32),
        }
        if mode == "train":
            batch["labels"] = jnp.zeros((B, S - P), jnp.int32)
        return batch
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    if mode == "train":
        batch["labels"] = jnp.zeros((B, S), jnp.int32)
    return batch


def abstract(tree):
    """Pytree -> ShapeDtypeStruct stand-ins (no allocation)."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
