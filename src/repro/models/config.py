"""Model configuration schema for the architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0               # 0 -> d_model // n_heads
    # ---- attention -------------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    global_attn_layers: tuple = ()  # hybrid: layers with full attention
    # ---- MLA (deepseek/minicpm) -------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # ---- MLP ---------------------------------------------------------------
    mlp_type: str = "swiglu"        # swiglu | gelu | relu2
    # ---- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_dense_prefix: int = 0       # first layers use a dense MLP
    capacity_factor: float = 1.25
    # ---- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0
    d_inner_mult: float = 2.0       # mamba inner expansion
    block_pattern: tuple = ()       # xlstm: ("m","s") repeated
    chunk_size: int = 256           # chunkwise-parallel scan chunk
    # ---- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    target_ratio: int = 8           # train target len = seq // target_ratio
    # ---- frontends (stubs) ----------------------------------------------------
    frontend: str = ""              # "" | vision_stub | audio_stub
    n_prefix_embeds: int = 0        # VLM: image tokens given as embeddings
    # ---- misc ------------------------------------------------------------------
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True        # lax.scan over homogeneous layer stack
    decode_absorb: bool = True      # MLA: absorbed (latent) decode path

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded so the vocab dim shards evenly (TP=16);
        padded logits are masked out of the loss/softmax."""
        mult = 1024 if self.vocab >= 1024 else 16
        return ((self.vocab + mult - 1) // mult) * mult

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (bounded attention state)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=128,
            vocab=256,
            head_dim=16 if self.head_dim else 0,
        )
        if self.is_moe:
            small.update(n_experts=4, top_k=2, d_ff_expert=64,
                         n_shared_experts=min(self.n_shared_experts, 1),
                         moe_dense_prefix=min(self.moe_dense_prefix, 1))
        if self.attn_type == "mla":
            small.update(kv_lora_rank=32, q_lora_rank=32, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16)
        if self.is_encoder_decoder:
            small.update(n_encoder_layers=2)
        if self.block_pattern:
            small.update(block_pattern=("m", "s"))
        if self.sliding_window:
            small.update(sliding_window=32)
        if self.global_attn_layers:
            small.update(global_attn_layers=(0,))
        if self.n_prefix_embeds:
            small.update(n_prefix_embeds=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, L = self.d_model, self.n_layers
        hd = self.hd
        if self.attn_type == "mla":
            q = (self.q_lora_rank and
                 d * self.q_lora_rank
                 + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                 ) or d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            kv = (d * (self.kv_lora_rank + self.qk_rope_dim)
                  + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim))
            o = self.n_heads * self.v_head_dim * d
            attn = q + kv + o
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        def mlp(ff):
            return (3 if self.mlp_type == "swiglu" else 2) * d * ff
        total = 0
        for i in range(L):
            total += attn
            if self.is_moe and i >= self.moe_dense_prefix:
                total += self.n_experts * mlp(self.d_ff_expert)
                total += self.n_shared_experts * mlp(self.d_ff_expert)
                total += d * self.n_experts  # router
            else:
                total += mlp(self.d_ff)
        if self.is_encoder_decoder:
            total += self.n_encoder_layers * (attn + mlp(self.d_ff)) \
                + L * attn  # cross attention
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k only)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        def mlp(ff):
            return (3 if self.mlp_type == "swiglu" else 2) * d * ff
        moe_layers = L - self.moe_dense_prefix
        inactive = moe_layers * (self.n_experts - self.top_k) * mlp(self.d_ff_expert)
        return full - inactive
