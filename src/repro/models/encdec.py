"""Whisper-style encoder-decoder backbone (audio family).

The mel/conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_frames, d). Sinusoidal absolute positions
(both sides — deviation from Whisper's learned decoder positions, noted in
DESIGN.md), LayerNorm, GELU MLP, no rope. Decoder self-attention cache is
sized WHISPER_MAX_TARGET; the cross-attention cache carries the (possibly
very long) encoder output — that is what scales with the seq_len shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import cs
from .attention import causal_mask, sdpa
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, dense_init, dtype_of, embed_init, \
    init_mlp, init_norm

WHISPER_MAX_TARGET = 448


def sinusoid(T: int, d: int, dtype):
    pos = np.arange(T)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * dim / d)
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype)


def _init_xattn(key, cfg: ModelConfig):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "w_q": dense_init(ks[0], (d, H * hd), dt),
        "w_k": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dt),
        "w_v": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dt),
        "w_o": dense_init(ks[3], (H * hd, d), dt),
    }


def _attend(p, xq, k, v, cfg: ModelConfig, mask):
    B, T, _ = xq.shape
    q = (xq @ p["w_q"]).reshape(B, T, cfg.n_heads, cfg.hd)
    out = sdpa(q, k, v, mask, 1.0 / np.sqrt(cfg.hd))
    return out.reshape(B, T, -1) @ p["w_o"]


def _kv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    k = (x @ p["w_k"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (x @ p["w_v"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def _init_enc_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg), "attn": _init_xattn(k1, cfg),
            "ln2": init_norm(cfg), "mlp": init_mlp(k2, cfg, cfg.d_ff)}


def _init_dec_block(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg), "self": _init_xattn(k1, cfg),
            "lnx": init_norm(cfg), "cross": _init_xattn(k2, cfg),
            "ln2": init_norm(cfg), "mlp": init_mlp(k3, cfg, cfg.d_ff)}


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed_tokens": embed_init(ks[2], (cfg.padded_vocab, cfg.d_model),
                                   dtype_of(cfg)),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": init_norm(cfg),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    B, S, d = frames.shape
    x = frames.astype(dtype_of(cfg)) + sinusoid(S, d, dtype_of(cfg))[None]
    x = cs(x, "batch", "seq", "embed")
    full = jnp.ones((1, S, S), bool)

    def body(xc, p):
        h = apply_norm(p["ln1"], xc, cfg)
        k, v = _kv(p["attn"], h, cfg)
        xc = xc + _attend(p["attn"], h, k, v, cfg, full)
        h = apply_norm(p["ln2"], xc, cfg)
        return xc + apply_mlp(p["mlp"], h, cfg), 0.0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(lambda c, p: body_fn(c, p), x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg)


def _decode_blocks(params, x, enc_out, cfg: ModelConfig, mode,
                   caches=None, pos=None):
    B, T, _ = x.shape
    if mode == "decode":
        self_mask = None  # built per step below
    else:
        self_mask = causal_mask(T, T)[None]
    enc_mask = jnp.ones((1, T, enc_out.shape[1]), bool) if enc_out is not None \
        else None

    def body(carry, layer):
        xc = carry
        p, cache = layer
        h = apply_norm(p["ln1"], xc, cfg)
        if mode == "decode":
            k1, v1 = _kv(p["self"], h, cfg)
            kc = jax.lax.dynamic_update_slice(cache["self"]["k"], k1,
                                              (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["self"]["v"], v1,
                                              (0, pos, 0, 0))
            m = (jnp.arange(kc.shape[1]) <= pos)[None, None, :]
            xc = xc + _attend(p["self"], h, kc, vc, cfg, m)
            new_cache = {"self": {"k": kc, "v": vc}, "cross": cache["cross"]}
            h = apply_norm(p["lnx"], xc, cfg)
            mC = jnp.ones((1, 1, cache["cross"]["k"].shape[1]), bool)
            xc = xc + _attend(p["cross"], h, cache["cross"]["k"],
                              cache["cross"]["v"], cfg, mC)
        else:
            k1, v1 = _kv(p["self"], h, cfg)
            xc = xc + _attend(p["self"], h, k1, v1, cfg, self_mask)
            h = apply_norm(p["lnx"], xc, cfg)
            ke, ve = _kv(p["cross"], enc_out, cfg)
            xc = xc + _attend(p["cross"], h, ke, ve, cfg, enc_mask)
            new_cache = ({"self": {"k": k1, "v": v1},
                          "cross": {"k": ke, "v": ve}}
                         if mode == "prefill" else jnp.zeros(()))
        h = apply_norm(p["ln2"], xc, cfg)
        return xc + apply_mlp(p["mlp"], h, cfg), new_cache

    body_fn = (jax.checkpoint(body)
               if (cfg.remat and mode == "train") else body)
    if caches is None:
        dummy = jnp.zeros(
            (jax.tree_util.tree_leaves(params["dec_layers"])[0].shape[0],),
            jnp.int32)

        def body2(c, layer):
            p, _ = layer
            return body_fn(c, (p, None))

        x, ncs = jax.lax.scan(body2, x, (params["dec_layers"], dummy))
    else:
        x, ncs = jax.lax.scan(body_fn, x, (params["dec_layers"], caches))
    return x, ncs


def _logits(params, x, cfg: ModelConfig):
    logits = x @ params["embed_tokens"].T
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def loss_fn(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    tokens, labels = batch["tokens"], batch["labels"]
    B, T = tokens.shape
    x = params["embed_tokens"][tokens] + \
        sinusoid(T, cfg.d_model, dtype_of(cfg))[None]
    x, _ = _decode_blocks(params, x, enc_out, cfg, "train")
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, x, cfg)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll, {"nll": nll, "aux": jnp.zeros(())}


def prefill(params, batch, cfg: ModelConfig):
    """Encode + run the decoder prompt, returning decode-ready caches."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = params["embed_tokens"][tokens] + \
        sinusoid(T, cfg.d_model, dtype_of(cfg))[None]
    x, caches = _decode_blocks(params, x, enc_out, cfg, "prefill")
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, x, cfg)
    return logits[:, -1:, :], {"dec": caches}


def decode_step(params, caches, token, pos, cfg: ModelConfig):
    x = params["embed_tokens"][token]
    T = x.shape[1]
    posv = sinusoid(WHISPER_MAX_TARGET, cfg.d_model, dtype_of(cfg))
    x = x + jax.lax.dynamic_slice(posv, (pos, 0), (1, cfg.d_model))[None]
    x, ncs = _decode_blocks(params, x, None, cfg, "decode",
                            caches=caches["dec"], pos=pos)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, x, cfg)
    return logits, {"dec": ncs}


def init_caches(cfg: ModelConfig, batch: int, enc_len: int):
    dt = dtype_of(cfg)
    L = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.hd
    self_kv = jnp.zeros((L, batch, WHISPER_MAX_TARGET, hkv, hd), dt)
    cross_kv = jnp.zeros((L, batch, enc_len, hkv, hd), dt)
    return {"dec": {"self": {"k": self_kv, "v": self_kv},
                    "cross": {"k": cross_kv, "v": cross_kv}}}
