"""Mixture-of-Experts FFN: sort-based capacity dispatch, two lowerings.

Token-choice top-k routing with two interchangeable dispatch paths:

1. ``_moe_forward_dense`` — single-program sort/scatter dispatch into an
   (E, C, d) buffer under auto-SPMD. Simple and correct, but at 256-device
   scale XLA lowers the global scatters into full (tokens*k, d) all-reduces
   (~240 GB per layer for kimi prefill; see EXPERIMENTS.md §Perf).

2. ``_moe_forward_ep`` — the production expert-parallel path: a shard_map
   interior where each device routes its local tokens, exchanges rows with
   its model-axis peers via two ``lax.all_to_all`` ops (payload
   N_loc*k*cf rows, ~500x smaller), sorts received rows into its E/G local
   experts, and runs the expert MLP locally. Expert weights arrive sharded
   (E over 'model', d over FSDP) and are all-gathered over the FSDP axes
   only (the standard FSDP weight gather). Capacity is enforced per shard
   (GShard/Switch semantics) rather than globally — drops can differ from
   the dense path when routing is skewed; with enough capacity_factor the
   two are numerically identical (tested).

The EP path activates when a mesh with a >1 'model' axis is installed via
``parallel.sharding.axis_rules(rules, mesh)`` and shapes divide; otherwise
the dense path runs (single-device smoke tests, decode micro-batches).

FLOPs are the honest active-FLOPs (tokens * top_k * cf * expert_mlp), not
the dense E-times blow-up.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..parallel.sharding import cs, current_mesh, current_rules
from .config import ModelConfig
from .layers import dense_init, dtype_of, init_mlp, mlp_einsum, apply_mlp

try:  # JAX >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def init_moe(key, cfg: ModelConfig):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    experts = {
        "w_up": dense_init(ks[0], (E, d, f), dt),
        "w_down": dense_init(ks[1], (E, f, d), dt),
    }
    if cfg.mlp_type == "swiglu":
        experts["w_gate"] = dense_init(ks[2], (E, d, f), dt)
    p = {
        "router": {"w": dense_init(ks[3], (d, E), jnp.float32, scale=0.1)},
        "experts": experts,
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, cfg.n_shared_experts * f)
    return p


#: EP lowering selector: "replicated" routes every model column over its dp
#: shard's tokens and combines expert groups with one psum (no activation
#: resharding — the measured winner, see EXPERIMENTS.md §Perf); "a2a"
#: exchanges token rows across the model axis with two all_to_alls
#: (smaller collective payload, but flattening tokens over dp x model forces
#: an activation reshard each layer that XLA lowers catastrophically).
EP_MODE = "replicated"


def moe_forward(p, x, cfg: ModelConfig):
    """x: (B, T, d) -> (out, aux_loss). Dispatch-path selection."""
    B, T, d = x.shape
    mesh = current_mesh()
    rules = current_rules()
    if mesh is not None and rules is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        G = sizes.get("model", 1)
        n_dev = mesh.devices.size
        dp_size = max(1, n_dev // G)
        if G > 1 and cfg.n_experts % G == 0:
            if EP_MODE == "a2a" and (B * T) % n_dev == 0:
                return _moe_forward_ep_a2a(p, x, cfg, mesh, rules)
            if EP_MODE == "replicated" and (B * T) % dp_size == 0:
                return _moe_forward_ep(p, x, cfg, mesh, rules)
    return _moe_forward_dense(p, x, cfg)


# ---------------------------------------------------------------------------
# Path 1: auto-SPMD dense dispatch (reference semantics)
# ---------------------------------------------------------------------------

def _moe_forward_dense(p, x, cfg: ModelConfig):
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * T
    xt = x.reshape(N, d)
    xt = cs(xt, "tokens_flat", None)

    gates = jax.nn.softmax((xt.astype(jnp.float32) @ p["router"]["w"]), axis=-1)
    gate_w, eidx = jax.lax.top_k(gates, k)                     # (N, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style) ----------------------
    me = gates.mean(0)                                          # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ---------------------------------------------
    F = N * k
    C = max(1, math.ceil(N * k / E * cfg.capacity_factor))
    flat_e = eidx.reshape(F)
    order = jnp.argsort(flat_e, stable=True)                    # (F,)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(F, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    dest_sorted = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # OOB -> drop
    tok_sorted = order // k

    xbuf = jnp.zeros((E * C, d), x.dtype).at[dest_sorted].set(
        xt[tok_sorted], mode="drop")
    xbuf = cs(xbuf.reshape(E, C, d), "experts", "expert_cap", None)

    ybuf = mlp_einsum(p["experts"], xbuf, cfg)                  # (E, C, d)
    ybuf = cs(ybuf, "experts", "expert_cap", None).reshape(E * C, d)

    # ---- combine -----------------------------------------------------------
    y_sorted = ybuf[jnp.minimum(dest_sorted, E * C - 1)]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_flat = jnp.zeros((F, d), x.dtype).at[order].set(y_sorted)  # unsort
    y = jnp.einsum("nkd,nk->nd", y_flat.reshape(N, k, d),
                   gate_w.astype(x.dtype))
    if "shared" in p:
        y = y + apply_mlp(p["shared"], xt, cfg)
    y = cs(y, "tokens_flat", None)
    return y.reshape(B, T, d), aux


# ---------------------------------------------------------------------------
# Path 2: expert-parallel shard_map interior (production lowering)
# ---------------------------------------------------------------------------

def _sort_into_bins(values_idx, n_bins: int, capacity: int):
    """Rank items by bin with a per-bin capacity (sort-based, no one-hot).

    values_idx: (R,) int bin id per item; ids >= n_bins are invalid/padding.
    Returns (order, dest, keep): items iterated in sorted order; item
    ``order[i]`` goes to flat slot ``dest[i]`` (bin * capacity + rank) when
    ``keep[i]`` — overflow and invalid ids are dropped.
    """
    R = values_idx.shape[0]
    order = jnp.argsort(values_idx, stable=True)
    sorted_b = values_idx[order]
    counts = jnp.zeros((n_bins + 1,), jnp.int32).at[
        jnp.minimum(values_idx, n_bins)].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(R, dtype=jnp.int32) - starts[jnp.minimum(sorted_b, n_bins)]
    keep = (pos < capacity) & (sorted_b < n_bins)
    dest = jnp.where(keep, sorted_b * capacity + pos, n_bins * capacity)
    return order, dest, keep


def _moe_forward_ep(p, x, cfg: ModelConfig, mesh, rules):
    """Replicated-routing EP: tokens stay dp-sharded end to end.

    Every device in a model row holds the same N/dp tokens (activations are
    replicated across 'model' for the token dim, exactly as in the dense
    layers). Each model column g routes those tokens, keeps only the pairs
    destined to its E/G local experts, runs them, and contributes a partial
    combine; one psum over 'model' completes the sum. Routing work (softmax
    + top_k over E) is duplicated G times — negligible next to the expert
    matmuls — and NO activation layout change ever happens, which is what
    makes this the fastest lowering measured (EXPERIMENTS.md §Perf).
    """
    B, T, d = x.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    N = B * T
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    G = sizes["model"]
    E_loc = E // G
    dp = tuple(a for a in rules.get("batch", ()) if a) or ()
    dp = dp if isinstance(dp, tuple) else (dp,)
    dp_size = max(1, mesh.devices.size // G)
    N_loc = N // dp_size
    c_exp = max(1, math.ceil(N_loc * k * cf / E))

    tok_spec = P(dp)
    w_specs = {"w_up": P("model", dp, None), "w_down": P("model", None, dp)}
    if "w_gate" in p["experts"]:
        w_specs["w_gate"] = P("model", dp, None)
    rw_spec = P(dp, None)

    def body(xt, rw, experts):
        # xt: (N_loc, d) — replicated across the model axis
        rw_full = jax.lax.all_gather(rw, dp, axis=0, tiled=True) if dp else rw
        wf = {name: jax.lax.all_gather(w, dp, axis=(1 if name != "w_down"
                                                    else 2), tiled=True)
              if dp else w for name, w in experts.items()}
        # Mark the replicated token/router values as VARYING over 'model'.
        # Numerically a no-op (all columns hold equal values), but it makes
        # shard_map's transpose insert the psum-over-'model' that the
        # cotangents of the varying-index gathers below require. Without
        # this the router / activation grads silently come back wrong
        # (caught by tests/helpers/moe_ep_check.py; see DESIGN.md §8).
        xt = jax.lax.pcast(xt, "model", to="varying")
        rw_full = jax.lax.pcast(rw_full, "model", to="varying")
        g_mine = jax.lax.axis_index("model")
        gates = jax.nn.softmax(xt.astype(jnp.float32) @ rw_full, axis=-1)
        gw, eidx = jax.lax.top_k(gates, k)
        gw = gw / jnp.clip(gw.sum(-1, keepdims=True), 1e-9)

        # every model column computes identical aux terms; pmean over
        # 'model' returns the (invarying) value while scaling cotangents by
        # 1/G — exactly cancelling the psum of G equal contributions.
        me = jax.lax.pmean(gates.mean(0), dp) if dp else gates.mean(0)
        ce_loc = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
            1.0) / (N_loc * k)
        ce = jax.lax.pmean(ce_loc, dp) if dp else ce_loc
        aux = jax.lax.pmean(E * jnp.sum(me * ce), "model")

        # Route INDICES, not rows: slot -> source-token maps are (R,)-sized,
        # so the only (rows x d) traffic is one gather into the expert
        # buffer and one scatter-add combine — R = E_loc*c_exp ~ F/G rows
        # instead of the F-row round-trips of the naive form (§Perf).
        F = N_loc * k
        flat_e = eidx.reshape(F)
        lb = flat_e - g_mine * E_loc
        local_bin = jnp.where((lb >= 0) & (lb < E_loc), lb, E_loc)
        order, dest, keep = _sort_into_bins(local_bin, E_loc, c_exp)
        R = E_loc * c_exp
        tok_slot = jnp.full((R + 1,), N_loc, jnp.int32).at[dest].set(
            order // k, mode="drop")[:-1]                    # (R,)
        gw_slot = jnp.zeros((R + 1,), jnp.float32).at[dest].set(
            gw.reshape(F)[order], mode="drop")[:-1]          # (R,)
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)])
        xexp = x_pad[tok_slot]                               # (R, d)
        yexp = mlp_einsum(wf, xexp.reshape(E_loc, c_exp, d), cfg)
        contrib = yexp.reshape(R, d) * gw_slot[:, None].astype(x.dtype)
        y = jnp.zeros((N_loc + 1, d), x.dtype).at[tok_slot].add(
            contrib)[:-1]
        return jax.lax.psum(y, "model"), aux

    xt = cs(x.reshape(N, d), "batch", None)
    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, rw_spec, w_specs),
        out_specs=(tok_spec, P()),
    )(xt, p["router"]["w"], p["experts"])
    y = y.reshape(B, T, d)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg)
    return cs(y, "batch", "seq", None), aux


def _moe_forward_ep_a2a(p, x, cfg: ModelConfig, mesh, rules):
    B, T, d = x.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    N = B * T
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    G = sizes["model"]                      # expert-parallel groups
    E_loc = E // G
    dp = tuple(a for a in rules.get("batch", ()) if a) or ()
    dp = dp if isinstance(dp, tuple) else (dp,)
    n_dev = mesh.devices.size
    N_loc = N // n_dev
    # per-shard capacities (GShard-style; slack at both levels)
    c_send = max(1, math.ceil(N_loc * k * cf / G))
    c_exp = max(1, math.ceil(G * c_send * cf / E_loc))

    tok_spec = P(dp + ("model",))
    w_specs = {
        "w_up": P("model", dp, None),
        "w_down": P("model", None, dp),
    }
    if "w_gate" in p["experts"]:
        w_specs["w_gate"] = P("model", dp, None)
    rw_spec = P(dp, None)

    def body(xt, rw, experts):
        # xt: (N_loc, d) local tokens; rw: (d/dp, E); experts: local shards
        rw_full = jax.lax.all_gather(rw, dp, axis=0, tiled=True) if dp else rw
        wf = {name: jax.lax.all_gather(w, dp, axis=(1 if name != "w_down"
                                                    else 2), tiled=True)
              if dp else w for name, w in experts.items()}
        gates = jax.nn.softmax(xt.astype(jnp.float32) @ rw_full, axis=-1)
        gw, eidx = jax.lax.top_k(gates, k)                  # (N_loc, k)
        gw = gw / jnp.clip(gw.sum(-1, keepdims=True), 1e-9)

        # aux loss (global means via psum over every mesh axis)
        all_axes = dp + ("model",)
        me = jax.lax.pmean(gates.mean(0), all_axes)
        ce_loc = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
            1.0) / (N_loc * k)
        ce = jax.lax.pmean(ce_loc, all_axes)
        aux = E * jnp.sum(me * ce)

        # ---- send-side: bin routed pairs by destination EP group ----------
        F = N_loc * k
        flat_e = eidx.reshape(F)
        grp = flat_e // E_loc
        order, dest, keep = _sort_into_bins(grp, G, c_send)
        tok_of = order // k
        pad_x = jnp.zeros((G * c_send + 1, d), x.dtype)
        send_x = pad_x.at[dest].set(xt[tok_of], mode="drop")[:-1]
        meta_e = jnp.full((G * c_send + 1,), E_loc, jnp.int32)
        send_e = meta_e.at[dest].set(flat_e[order] % E_loc, mode="drop")[:-1]

        # ---- exchange rows with model-axis peers --------------------------
        recv_x = jax.lax.all_to_all(send_x.reshape(G, c_send, d), "model",
                                    split_axis=0, concat_axis=0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e.reshape(G, c_send), "model",
                                    split_axis=0, concat_axis=0, tiled=False)
        rows = G * c_send

        # ---- group received rows by local expert --------------------------
        re = recv_e.reshape(rows)
        order2, dest2, keep2 = _sort_into_bins(re, E_loc, c_exp)
        pad2 = jnp.zeros((E_loc * c_exp + 1, d), x.dtype)
        xexp = pad2.at[dest2].set(recv_x.reshape(rows, d)[order2],
                                  mode="drop")[:-1]
        yexp = mlp_einsum(wf, xexp.reshape(E_loc, c_exp, d), cfg)

        # ---- ungroup, return rows, combine ---------------------------------
        y_sorted = yexp.reshape(-1, d)[jnp.minimum(dest2, E_loc * c_exp - 1)]
        y_sorted = jnp.where(keep2[:, None], y_sorted, 0)
        y_rows = jnp.zeros((rows, d), x.dtype).at[order2].set(y_sorted)
        back = jax.lax.all_to_all(y_rows.reshape(G, c_send, d), "model",
                                  split_axis=0, concat_axis=0, tiled=False)
        y_slot = back.reshape(rows, d)[jnp.minimum(dest, rows - 1)]
        y_slot = jnp.where(keep[:, None], y_slot, 0)
        y_pairs = jnp.zeros((F, d), x.dtype).at[order].set(y_slot)
        y = jnp.einsum("nkd,nk->nd", y_pairs.reshape(N_loc, k, d),
                       gw.astype(x.dtype))
        return y, aux

    xt = cs(x.reshape(N, d), "tokens_flat", None)
    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, rw_spec, w_specs),
        out_specs=(tok_spec, P()),
    )(xt, p["router"]["w"], p["experts"])
    # hand the activation back in the attention-friendly (batch, seq) layout
    # — an explicit reshard, instead of letting SPMD full-rematerialize when
    # the (tokens over dp x model) flat layout leaks through the reshape.
    y = cs(y.reshape(B, T, d), "batch", "seq", None)
    if "shared" in p:
        # shared experts are dense token-pointwise MLPs; run them in the
        # batch/seq layout (d_ff sharded over 'model') like any dense FFN.
        y = y + apply_mlp(p["shared"], cs(x, "batch", "seq", None), cfg)
    return y, aux
