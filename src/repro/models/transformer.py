"""Decoder-only LM assembly for dense / MoE / VLM / SSM / hybrid families.

Pure-functional: ``init_params`` builds the param pytree (stacked layers for
lax.scan on deep homogeneous stacks), ``loss_fn`` / ``prefill`` /
``decode_step`` are the three entry points the launchers jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..parallel.sharding import cs
from .attention import (
    gqa_cache_spec, gqa_decode, gqa_forward,
    mla_cache_spec, mla_decode, mla_forward,
)
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, dtype_of, embed_init, init_mlp, init_norm
from .moe import init_moe, moe_forward
from .ssm import (
    init_mamba, init_mlstm, init_slstm,
    mamba_decode, mamba_forward, mamba_state,
    mlstm_decode, mlstm_forward, mlstm_state,
    slstm_decode, slstm_forward, slstm_state,
)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig):
    from .attention import init_gqa, init_mla
    return init_mla(key, cfg) if cfg.attn_type == "mla" else init_gqa(key, cfg)


def init_block(key, cfg: ModelConfig, moe: bool, kind: str = "attn"):
    """kind: attn | hybrid | m | s (xlstm blocks)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg)}
    if kind == "attn":
        p["attn"] = _init_attn(k1, cfg)
    elif kind == "hybrid":
        p["attn"] = _init_attn(k1, cfg)
        p["ssm"] = init_mamba(k4, cfg, d_out=cfg.d_model)
    elif kind == "m":
        p["mix"] = init_mlstm(k1, cfg)
    elif kind == "s":
        p["mix"] = init_slstm(k1, cfg)
    if cfg.d_ff > 0 and kind in ("attn", "hybrid"):
        p["ln2"] = init_norm(cfg)
        p["moe" if moe else "mlp"] = (
            init_moe(k2, cfg) if moe else init_mlp(k2, cfg, cfg.d_ff))
    return p


def _apply_ffn(p, x, cfg: ModelConfig):
    """Returns (delta, aux)."""
    if "moe" in p:
        h = apply_norm(p["ln2"], x, cfg)
        y, aux = moe_forward(p["moe"], h, cfg)
        return y, aux
    if "mlp" in p:
        h = apply_norm(p["ln2"], x, cfg)
        return apply_mlp(p["mlp"], h, cfg), 0.0
    return jnp.zeros_like(x), 0.0


def block_forward(p, x, cfg: ModelConfig, kind: str, window: int,
                  mode: str, cache=None, pos=None, state=None):
    """One block; returns (x, new_cache_or_state, aux)."""
    h = apply_norm(p["ln1"], x, cfg)
    aux = 0.0
    if kind == "attn":
        if mode == "decode":
            if cfg.attn_type == "mla":
                a, nc = mla_decode(p["attn"], h, cache, pos, cfg)
            else:
                a, nc = gqa_decode(p["attn"], h, cache, pos, cfg, window)
        else:
            if cfg.attn_type == "mla":
                a, nc = mla_forward(p["attn"], h, cfg)
            else:
                a, nc = gqa_forward(p["attn"], h, cfg, window=window)
        x = x + a
    elif kind == "hybrid":
        if mode == "decode":
            a, nc_attn = gqa_decode(p["attn"], h, cache["attn"], pos, cfg, window)
            s, nc_ssm = mamba_decode(p["ssm"], h, cache["ssm"], cfg)
        else:
            a, nc_attn = gqa_forward(p["attn"], h, cfg, window=window)
            s, nc_ssm = mamba_forward(p["ssm"], h, cfg)
        x = x + 0.5 * (a + s)
        nc = {"attn": nc_attn, "ssm": nc_ssm}
    elif kind in ("m", "s"):
        fwd = {"m": (mlstm_forward, mlstm_decode),
               "s": (slstm_forward, slstm_decode)}[kind]
        if mode == "decode":
            a, nc = fwd[1](p["mix"], h, cache, cfg)
        else:
            a, nc = fwd[0](p["mix"], h, cfg, state=cache)
        x = x + a
    else:
        raise ValueError(kind)
    y, aux = _apply_ffn(p, x, cfg)
    return x + y, nc, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def _layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "ssm":
        pat = cfg.block_pattern or ("m", "s")
        return [pat[i % len(pat)] for i in range(cfg.n_layers)]
    if cfg.family == "hybrid":
        return ["hybrid"] * cfg.n_layers
    return ["attn"] * cfg.n_layers


def _layer_windows(cfg: ModelConfig) -> list[int]:
    out = []
    for i in range(cfg.n_layers):
        if cfg.sliding_window and i not in cfg.global_attn_layers:
            out.append(cfg.sliding_window)
        else:
            out.append(0)
    return out


def uses_scan(cfg: ModelConfig) -> bool:
    """Scan only over deep, fully homogeneous attention stacks."""
    return (cfg.scan_layers and cfg.family in ("dense", "moe", "vlm")
            and not cfg.sliding_window)


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 4)
    dt = dtype_of(cfg)
    params = {
        "embed_tokens": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dt),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[1], (cfg.d_model, cfg.padded_vocab), dt)
    kinds = _layer_kinds(cfg)
    n_prefix = cfg.moe_dense_prefix if cfg.is_moe else 0
    if uses_scan(cfg):
        # dense prefix blocks stay unstacked; the homogeneous tail is stacked.
        params["prefix"] = [
            init_block(ks[2 + i], cfg, moe=False) for i in range(n_prefix)]
        tail = cfg.n_layers - n_prefix
        keys = jax.random.split(ks[2 + n_prefix], tail)
        params["layers"] = jax.vmap(
            lambda k: init_block(k, cfg, moe=cfg.is_moe))(keys)
    else:
        params["blocks"] = [
            init_block(ks[2 + i], cfg, moe=cfg.is_moe and i >= n_prefix,
                       kind=kinds[i])
            for i in range(cfg.n_layers)]
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (+ optional prefix embeds for VLM) -> (B, T, d)."""
    x = params["embed_tokens"][batch["tokens"]]
    if cfg.n_prefix_embeds and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    return cs(x, "batch", "seq", "embed")


def _lm_logits(params, x, cfg: ModelConfig):
    x = apply_norm(params["final_norm"], x, cfg)
    head = (params["embed_tokens"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = x @ head
    if cfg.padded_vocab != cfg.vocab:  # mask padding columns out of softmax
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return cs(logits, "batch", "seq", "vocab")


def _scan_stack(params, x, cfg: ModelConfig, mode: str, caches=None, pos=None):
    """lax.scan over the stacked homogeneous layers."""
    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, layer):
        xc, aux = carry
        p, cache = layer
        xc, nc, a = block_forward(p, xc, cfg, "attn", 0, mode,
                                  cache=cache, pos=pos)
        return (xc, aux + a), nc

    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    n_tail = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    if caches is None:
        # scan xs must be arrays: thread a dummy per-layer token instead of
        # the (absent) cache; drop the produced caches in train mode so the
        # full-sequence K/V stacks are never materialized.
        dummy = jnp.zeros((n_tail,), jnp.int32)

        def body2(carry, layer):
            p, _ = layer
            out, nc = body_fn(carry, (p, None))
            return out, (nc if mode == "prefill" else jnp.zeros(()))

        (x, aux), new_caches = jax.lax.scan(body2, (x, aux0),
                                            (params["layers"], dummy))
    else:
        (x, aux), new_caches = jax.lax.scan(body_fn, (x, aux0),
                                            (params["layers"], caches))
    return x, aux, new_caches


def forward(params, batch, cfg: ModelConfig, mode: str = "train",
            caches=None, pos=None):
    """Full-sequence forward. Returns (logits, aux, caches)."""
    x = _embed_inputs(params, batch, cfg)
    aux = jnp.zeros((), jnp.float32)
    kinds = _layer_kinds(cfg)
    windows = _layer_windows(cfg)
    new_caches: dict = {}
    want_cache = mode == "prefill"
    if uses_scan(cfg):
        pcaches = []
        for i, bp in enumerate(params.get("prefix", [])):
            x, nc, a = block_forward(bp, x, cfg, "attn", 0, mode)
            aux += a
            pcaches.append(nc)
        x, a, stacked = _scan_stack(params, x, cfg,
                                    "prefill" if want_cache else "train")
        aux += a
        if want_cache:
            new_caches = {"prefix": pcaches, "layers": stacked}
    else:
        blocks_c = []
        for i, bp in enumerate(params["blocks"]):
            fn = (jax.checkpoint(block_forward,
                                 static_argnums=(2, 3, 4, 5))
                  if (cfg.remat and mode == "train") else block_forward)
            x, nc, a = fn(bp, x, cfg, kinds[i], windows[i], mode)
            aux += a
            blocks_c.append(nc)
        if want_cache:
            new_caches = {"blocks": blocks_c}
    logits = _lm_logits(params, x, cfg)
    return logits, aux, (new_caches if want_cache else None)


def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token cross-entropy (+ MoE aux). batch: tokens (B, T), labels (B, T)."""
    logits, aux, _ = forward(params, batch, cfg, mode="train")
    labels = batch["labels"]
    if cfg.n_prefix_embeds and "prefix_embeds" in batch:
        # only text positions have labels; image prefix is unsupervised
        logits = logits[:, batch["prefix_embeds"].shape[1]:, :]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


def prefill(params, batch, cfg: ModelConfig):
    """Returns (last-position logits, caches) for subsequent decode."""
    logits, _, caches = forward(params, batch, cfg, mode="prefill")
    return logits[:, -1:, :], caches


def decode_step(params, caches, token, pos, cfg: ModelConfig):
    """One decode step. token: (B, 1) int32; pos: scalar int32."""
    x = params["embed_tokens"][token]
    x = cs(x, "batch", None, "embed")
    kinds = _layer_kinds(cfg)
    windows = _layer_windows(cfg)
    if uses_scan(cfg):
        new_prefix = []
        for bp, c in zip(params.get("prefix", []), caches.get("prefix", [])):
            x, nc, _ = block_forward(bp, x, cfg, "attn", 0, "decode",
                                     cache=c, pos=pos)
            new_prefix.append(nc)
        x, _, stacked = _scan_stack(params, x, cfg, "decode",
                                    caches=caches["layers"], pos=pos)
        new_caches = {"prefix": new_prefix, "layers": stacked}
    else:
        blocks_c = []
        for i, (bp, c) in enumerate(zip(params["blocks"], caches["blocks"])):
            x, nc, _ = block_forward(bp, x, cfg, kinds[i], windows[i],
                                     "decode", cache=c, pos=pos)
            blocks_c.append(nc)
        new_caches = {"blocks": blocks_c}
    logits = _lm_logits(params, x, cfg)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _one_cache(cfg: ModelConfig, kind: str, window: int, batch: int, seq: int):
    if kind == "attn":
        if cfg.attn_type == "mla":
            return mla_cache_spec(cfg, batch, seq)
        return gqa_cache_spec(cfg, batch, seq, window)
    if kind == "hybrid":
        return {"attn": gqa_cache_spec(cfg, batch, seq, window),
                "ssm": mamba_state(cfg, batch)}
    if kind == "m":
        return mlstm_state(cfg, batch)
    if kind == "s":
        return slstm_state(cfg, batch)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, seq: int):
    """Zero caches sized for a seq_len-token context (dry-run: via eval_shape)."""
    kinds = _layer_kinds(cfg)
    windows = _layer_windows(cfg)
    if uses_scan(cfg):
        n_prefix = cfg.moe_dense_prefix if cfg.is_moe else 0
        tail = cfg.n_layers - n_prefix
        one = _one_cache(cfg, "attn", 0, batch, seq)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail,) + a.shape), one)
        return {"prefix": [_one_cache(cfg, "attn", 0, batch, seq)
                           for _ in range(n_prefix)],
                "layers": stacked}
    return {"blocks": [
        _one_cache(cfg, kinds[i], windows[i], batch, seq)
        for i in range(cfg.n_layers)]}
