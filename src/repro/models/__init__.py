from .config import ModelConfig
from . import api

__all__ = ["ModelConfig", "api"]
