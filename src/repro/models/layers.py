"""Shared building blocks: norms, rotary embeddings, MLPs, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers — params are created lazily; dry-run uses jax.eval_shape.
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype_of(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype_of(cfg))
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps):
    """Per-head q/k RMS norm (qwen3 qk_norm). x: (..., hd)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_tables(positions, dim: int, theta: float):
    """positions: (...,) int -> cos/sin of shape (..., dim)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., dim); cos/sin: broadcastable (..., dim//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, d_ff), dt),
            "w_up": dense_init(ks[1], (d, d_ff), dt),
            "w_down": dense_init(ks[2], (d_ff, d), dt),
        }
    return {
        "w_up": dense_init(ks[0], (d, d_ff), dt),
        "w_down": dense_init(ks[1], (d_ff, d), dt),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]


def mlp_einsum(ws, x, cfg: ModelConfig):
    """Batched-expert MLP: ws leaves have a leading expert axis E.

    x: (E, C, d) -> (E, C, d).
    """
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, ws["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", x, ws["w_up"])
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", x, ws["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, ws["w_up"]),
                        approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, ws["w_down"])
