"""Level-synchronous vectorized SOAR-Gather (beyond-paper optimization).

The paper (Sec. 5.4) evaluates a *serial, centralized* SOAR-Gather and leaves
a parallel implementation as future work. Here we restructure the gather as a
level-synchronous sweep: all nodes of a depth level are processed together,
and the budget-split min over children (the mCost min-plus convolution) is a
single *batched* tropical convolution over (node, ell) rows. This is the form
that maps onto TPU (see kernels/minplus for the Pallas kernel); on CPU it is
executed by numpy/jnp vector units.

Also implements the subtree-budget **cap** optimization: a subtree with s
available switches can never use more than min(k, s) blues, so convolutions
are truncated to the useful prefix (classic tree-knapsack bound) — an
asymptotic win the paper does not exploit.
"""
from __future__ import annotations

import numpy as np

from .soar import SoarResult, soar_color
from .tree import Tree
from .tropical import minplus_batch  # noqa: F401  (re-exported batched primitive)


def _levels(t: Tree) -> list[np.ndarray]:
    out = [[] for _ in range(t.height + 1)]
    for v in range(t.n):
        out[t.depth[v]].append(v)
    return [np.asarray(l, dtype=np.int64) for l in out]


def soar_gather_vectorized(
    t: Tree,
    load: np.ndarray,
    k: int,
    avail: np.ndarray | None = None,
) -> np.ndarray:
    """Dense DP tables X_all[v, ell, i], rows beyond D(v)+1 unused (inf)."""
    load = np.asarray(load, dtype=np.int64)
    avail = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    K = k + 1
    h = t.height
    R = t.rho_up_table()  # (n, h+2)
    send = (t.subtree_loads(load) > 0).astype(np.int64)
    X = np.full((t.n, h + 2, K), np.inf)
    levels = _levels(t)
    max_c = max((len(t.children[v]) for v in range(t.n)), default=0)
    # child index matrix: kid[v, m] = m-th child or -1
    kid = np.full((t.n, max(max_c, 1)), -1, dtype=np.int64)
    for v in range(t.n):
        for m, c in enumerate(t.children[v]):
            kid[v, m] = c

    for d in range(h, -1, -1):
        nodes = levels[d]
        nl = d + 2  # valid ell rows 0..d+1
        is_leaf = np.asarray([len(t.children[v]) == 0 for v in nodes])
        # ---- leaves ----------------------------------------------------
        lv = nodes[is_leaf]
        if len(lv):
            rl = R[lv, :nl]                                   # (B, nl)
            red = load[lv, None, None] * rl[:, :, None] * np.ones(K)
            blue = np.full_like(red, np.inf)
            can = avail[lv] & (k >= 1)
            blue[can, :, 1:] = (send[lv][can, None] * rl[can])[:, :, None]
            X[lv, :nl, :] = np.minimum(red, blue)
        # ---- internal nodes --------------------------------------------
        iv = nodes[~is_leaf]
        if len(iv):
            nc = np.asarray([len(t.children[v]) for v in iv])
            # red chain: child rows 1..nl (aligned to our rows 0..nl-1)
            acc_r = X[kid[iv, 0], 1 : nl + 1, :].copy()       # (B, nl, K)
            acc_b = X[kid[iv, 0], 1, :].copy()                # (B, K)
            for m in range(1, int(nc.max())):
                sel = nc > m
                c = kid[iv[sel], m]
                a = acc_r[sel].reshape(-1, K)
                b = X[c, 1 : nl + 1, :].reshape(-1, K)
                acc_r[sel] = minplus_batch(a, b).reshape(-1, nl, K)
                acc_b[sel] = minplus_batch(acc_b[sel], X[c, 1, :])
            rl = R[iv, :nl]
            red = acc_r + (load[iv, None] * rl)[:, :, None]
            blue = np.full_like(red, np.inf)
            can = avail[iv] & (k >= 1)
            blue[can, :, 1:] = (
                acc_b[can, None, :-1] + (send[iv][can, None] * rl[can])[:, :, None]
            )
            out = np.minimum(red, blue)
            np.minimum.accumulate(out, axis=2, out=out)
            X[iv, :nl, :] = out
    return X


def soar_fast(
    t: Tree,
    load: np.ndarray,
    k: int,
    avail: np.ndarray | None = None,
) -> SoarResult:
    """SOAR with the vectorized gather; identical output contract to soar()."""
    X_all = soar_gather_vectorized(t, load, k, avail)
    cost = float(X_all[t.root, 1, k])
    tables = [X_all[v] for v in range(t.n)]
    blue = soar_color(t, load, k, tables, avail)
    return SoarResult(blue=blue, cost=cost, tables=None)
