"""SOAR: optimal dynamic program for the phi-BIC problem (paper Sec. 4/6).

Faithful reference implementation of Algorithms 2-4 (SOAR = SOAR-Gather +
SOAR-Color), with the recurrences of Lemma 6.1/6.2:

  X_v(l, i)  = min cost contribution of subtree T_v — internal utilization plus
               the messages leaving v, charged along the l hops up to v's
               closest blue ancestor (or d) — using at most i blue nodes in T_v.

  v red :  X_v(l, i) = minplus_{children}(X_c(l+1, .))[i] + L(v) * rho(v, A_v^l)
  v blue:  X_v(l, i) = minplus_{children}(X_c(1,   .))[i-1] + send(v) * rho(v, A_v^l)

where ``minplus`` is the min-plus (tropical) convolution over the children's
budget split (the paper's mCost / procedure lines 30-34 of Alg. 3), and
``send(v) = 1`` iff T_v holds positive load (see DESIGN.md §8 for the two
at-most-k / zero-load deviations, both strictly-dominating refinements).

Semantics notes vs. the paper's pseudo-code:
  * "at most k" (Def. 2.1 prose) rather than "exactly k" (Eq. 2): tables are
    monotone non-increasing in i, which the traceback exploits.
  * l ranges over 0..D(v)+1 (the +1 reaching d) — fixes the paper's Sec. 4.2
    off-by-one (the root needs l = 1, Eq. 6).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .tree import DEST, Tree
from .tropical import minplus  # noqa: F401  (re-exported: the DP's primitive)


@dataclasses.dataclass
class SoarResult:
    blue: np.ndarray          # (n,) bool mask of aggregating switches
    cost: float               # optimal phi(T, L, U)
    tables: list | None       # per-node X_v tables (gather output), if kept


def _send(t: Tree, load: np.ndarray) -> np.ndarray:
    """send(v): messages a blue v emits = 1 iff subtree load positive."""
    return (t.subtree_loads(load) > 0).astype(np.int64)


# ---------------------------------------------------------------------------
# SOAR-Gather (Algorithm 3)
# ---------------------------------------------------------------------------

def soar_gather(
    t: Tree,
    load: np.ndarray,
    k: int,
    avail: np.ndarray | None = None,
    cap: bool = True,
) -> list[np.ndarray]:
    """Bottom-up DP table construction.

    Returns per-node tables ``X[v]`` of shape (D(v)+2, k+1): rows are the
    distance l to the closest blue ancestor (or d), columns the blue budget.

    ``cap=True`` enables the subtree-budget cap (beyond-paper): a subtree with
    s available switches is convolved only up to min(k, s) budget columns,
    then flat-padded (tables are monotone). ``cap=False`` is the paper's
    verbatim O(n h k^2) loop structure.
    """
    load = np.asarray(load, dtype=np.int64)
    avail = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    K = k + 1
    R = t.rho_up_table()              # R[v, l] = rho(v, A_v^l)
    send = _send(t, load)
    # number of available switches in each subtree -> max useful budget
    navail = avail.astype(np.int64).copy()
    for u in t.topo[::-1]:
        p = t.parent[u]
        if p != DEST:
            navail[p] += navail[u]
    W = np.minimum(navail, k) + 1 if cap else np.full(t.n, K, dtype=np.int64)
    X: list[np.ndarray | None] = [None] * t.n

    for v in t.topo[::-1]:            # leaves towards the root
        d_v = int(t.depth[v])
        nl = d_v + 2                  # valid l values: 0 .. D(v)+1
        rl = R[v, :nl][:, None]       # (nl, 1)
        kids = t.children[v]
        w = int(W[v])
        if not kids:
            Xv = load[v] * rl * np.ones((1, w))
            if avail[v] and w >= 2:
                Xv[:, 1:] = np.minimum(Xv[:, 1:], send[v] * rl)
        else:
            # red: children see their barrier l+1 hops up -> child rows 1..nl.
            # (child tables have nl+1 rows; rows l+1 align with our rows l)
            conv_r = X[kids[0]][1 : nl + 1, :w]
            for c in kids[1:]:
                conv_r = minplus(conv_r, X[c][1 : nl + 1, :w], out_w=w)
            Xv = np.full((nl, w), np.inf)
            cw = conv_r.shape[1]
            Xv[:, :cw] = conv_r + load[v] * rl
            if cw < w:
                Xv[:, cw:] = Xv[:, cw - 1 : cw]
            if avail[v] and w >= 2:
                # blue: children see the barrier at distance 1 (v itself).
                conv_b = X[kids[0]][1:2, : w - 1]
                for c in kids[1:]:
                    conv_b = minplus(conv_b, X[c][1:2, : w - 1], out_w=w - 1)
                blue = np.full((nl, w), np.inf)
                bw = conv_b.shape[1]
                blue[:, 1 : 1 + bw] = conv_b + send[v] * rl
                if 1 + bw < w:
                    blue[:, 1 + bw :] = blue[:, bw : bw + 1]
                Xv = np.minimum(Xv, blue)
        # at-most-k monotonicity (defensive; holds by induction)
        np.minimum.accumulate(Xv, axis=1, out=Xv)
        if w < K:  # flat-pad so downstream budget indexing is unconstrained
            Xv = np.concatenate([Xv, np.repeat(Xv[:, -1:], K - w, axis=1)], axis=1)
        X[v] = Xv
    return X  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# SOAR-Color (Algorithm 4)
# ---------------------------------------------------------------------------

def _partial_convs(X, kids, row) -> list[np.ndarray]:
    """Partial min-plus chain Y^m over children at a fixed l row (1D, K)."""
    out = [X[kids[0]][row]]
    for c in kids[1:]:
        out.append(minplus(out[-1], X[c][row])[0])
    return out


def soar_color(
    t: Tree,
    load: np.ndarray,
    k: int,
    X: list[np.ndarray],
    avail: np.ndarray | None = None,
) -> np.ndarray:
    """Top-down traceback of the optimal coloring along the DP tables."""
    load = np.asarray(load, dtype=np.int64)
    avail = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    R = t.rho_up_table()
    send = _send(t, load)
    blue = np.zeros(t.n, dtype=bool)
    # (node, budget i for T_v, l* = distance to closest blue ancestor / d)
    stack: list[tuple[int, int, int]] = [(t.root, k, 1)]
    while stack:
        v, i, ell = stack.pop()
        kids = t.children[v]
        rl = R[v, ell]
        if not kids:
            red_val = load[v] * rl
            blue_val = send[v] * rl if (avail[v] and i >= 1) else np.inf
            if blue_val < red_val:
                blue[v] = True
            continue
        conv_r = _partial_convs(X, kids, ell + 1)
        red_val = conv_r[-1][i] + load[v] * rl
        if avail[v] and i >= 1:
            conv_b = _partial_convs(X, kids, 1)
            blue_val = conv_b[-1][i - 1] + send[v] * rl
        else:
            conv_b, blue_val = None, np.inf
        if blue_val < red_val:
            blue[v] = True
            budget, lc, chain = i - 1, 1, conv_b
        else:
            budget, lc, chain = i, ell + 1, conv_r
        # split the budget among children, last child first (mSplit replay)
        for m in range(len(kids) - 1, 0, -1):
            c = kids[m]
            prev = chain[m - 1]
            best_j, best_val = 0, np.inf
            for j in range(budget + 1):
                val = prev[budget - j] + X[c][lc][j]
                if val < best_val:
                    best_val, best_j = val, j
            stack.append((c, best_j, lc))
            budget -= best_j
        stack.append((kids[0], budget, lc))
    return blue


# ---------------------------------------------------------------------------
# SOAR (Algorithm 2)
# ---------------------------------------------------------------------------

def soar(
    t: Tree,
    load: np.ndarray,
    k: int,
    avail: np.ndarray | None = None,
    keep_tables: bool = False,
    cap: bool = True,
) -> SoarResult:
    """Optimal phi-BIC solution with |U| <= k (Theorem 4.1)."""
    if k < 0:
        raise ValueError("budget k must be non-negative")
    X = soar_gather(t, load, k, avail, cap=cap)
    cost = float(X[t.root][1, k])
    blue = soar_color(t, load, k, X, avail)
    return SoarResult(blue=blue, cost=cost, tables=X if keep_tables else None)
