"""Contending placement strategies from the paper (Sec. 3, Appendix B).

Every strategy returns a boolean blue mask with at most k True entries,
restricted to the available set Lambda.
"""
from __future__ import annotations

import numpy as np

from .tree import Tree


def _avail_idx(t: Tree, avail) -> np.ndarray:
    avail = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    return np.nonzero(avail)[0]


def _mask(t: Tree, picks) -> np.ndarray:
    m = np.zeros(t.n, bool)
    m[np.asarray(list(picks), dtype=np.int64)] = True
    return m


def top(t: Tree, load, k: int, avail=None, seed: int = 0) -> np.ndarray:
    """Top: the k available switches closest to the root (Sec. 3 (i)).

    Equal-depth ties are broken towards the heavier subtree, which matches
    the paper's Fig. 2a (Top = {root, right mid} with cost 27).
    """
    cand = _avail_idx(t, avail)
    sload = t.subtree_loads(np.asarray(load))
    order = cand[np.lexsort((cand, -sload[cand], t.depth[cand]))]
    return _mask(t, order[:k])


def max_load(t: Tree, load, k: int, avail=None, seed: int = 0) -> np.ndarray:
    """Max: the k available switches with the largest load (Sec. 3 (ii))."""
    load = np.asarray(load)
    cand = _avail_idx(t, avail)
    order = cand[np.lexsort((cand, -load[cand]))]
    return _mask(t, order[:k])


def max_degree(t: Tree, load, k: int, avail=None, seed: int = 0) -> np.ndarray:
    """Max-degree variant used for scale-free networks (Appendix B)."""
    cand = _avail_idx(t, avail)
    deg = np.asarray([t.degree(int(v)) for v in cand])
    order = cand[np.lexsort((cand, -deg))]
    return _mask(t, order[:k])


def level(t: Tree, load, k: int, avail=None, seed: int = 0) -> np.ndarray:
    """Level: a whole level of a complete binary tree (Sec. 3 (iii)).

    Picks the deepest complete level whose size fits the budget:
    level j holds 2^j switches, so j = floor(log2(k)) (clipped to the height).
    Only switches in Lambda are taken (the paper assumes Lambda = S).
    """
    if k < 1:
        return np.zeros(t.n, bool)
    j = min(int(np.floor(np.log2(k))), t.height)
    availm = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    picks = [v for v in range(t.n) if t.depth[v] == j and availm[v]]
    return _mask(t, picks[:k]) if picks else np.zeros(t.n, bool)


def random_k(t: Tree, load, k: int, avail=None, seed: int = 0) -> np.ndarray:
    """Uniformly random placement (sanity baseline)."""
    rng = np.random.default_rng(seed)
    cand = _avail_idx(t, avail)
    picks = rng.choice(cand, size=min(k, len(cand)), replace=False)
    return _mask(t, picks)


STRATEGIES = {
    "top": top,
    "max": max_load,
    "max_degree": max_degree,
    "level": level,
    "random": random_k,
}
