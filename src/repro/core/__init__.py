"""SOAR core: the paper's contribution (phi-BIC optimal placement)."""
from .baselines import STRATEGIES, level, max_degree, max_load, random_k, top
from .brute import brute_force
from .bytes_model import ParameterServerModel, WordCountModel, byte_complexity
from .forest import Forest, build_forest
from .online import OnlineResult, online_allocate, workload_stream
from .reduce import all_blue, all_red, mask_from_set, messages_up, phi, phi_barrier
from .soar import SoarResult, minplus, soar, soar_color, soar_gather
from .soar_fast import minplus_batch, soar_fast, soar_gather_vectorized
from .tree import DEST, Tree, bt, random_tree, rpa, sample_load, with_rates

__all__ = [
    "DEST", "Tree", "bt", "rpa", "random_tree", "sample_load", "with_rates",
    "soar", "soar_fast", "soar_gather", "soar_gather_vectorized", "soar_color",
    "SoarResult", "minplus", "minplus_batch",
    "phi", "phi_barrier", "messages_up", "all_red", "all_blue", "mask_from_set",
    "Forest", "build_forest",
    "brute_force", "STRATEGIES", "top", "max_load", "max_degree", "level",
    "random_k", "online_allocate", "workload_stream", "OnlineResult",
    "byte_complexity", "WordCountModel", "ParameterServerModel",
]
