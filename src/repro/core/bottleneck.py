"""Bottleneck-BIC: minimize the maximum per-link utilization (paper §8).

The paper leaves "minimizing the load on bottleneck links" as future work
and conjectures it correlates with the utilization objective. We solve it
exactly with a Pareto-frontier dynamic program and use it to TEST the
conjecture (benchmarks/beyond_bottleneck.py).

Objective:   lambda(T, L, U) = max_e  msg_e(T, L, U) * rho(e)

Why SOAR's table doesn't directly apply: phi is linear in per-edge message
counts, so the closest-blue-ancestor trick collapses the state to a
distance l. The bottleneck couples edges through the *message count*
crossing them, so the DP state must carry it: each subtree reports the
Pareto frontier of

    (m, b) = (messages leaving the subtree upward,
              bottleneck among edges inside + the root's up-edge)

per budget i and color choice. Combining children sums m and maxes b;
frontiers are pruned to non-dominated pairs (sorted by m, strictly
decreasing b), which keeps them small in practice (distinct useful m
values are few). Exactness is property-tested against brute force.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .reduce import messages_up
from .tree import DEST, Tree


def bottleneck_phi(t: Tree, load, blue) -> float:
    """lambda(T, L, U): max over edges of msg_e * rho(e) (simulator)."""
    msgs = messages_up(t, np.asarray(load), np.asarray(blue, bool))
    return float(np.max(msgs * t.rho))


@dataclasses.dataclass
class _Entry:
    m: int                  # messages leaving the subtree
    b: float                # bottleneck so far (incl. root's up-edge)
    color: bool             # this node blue?
    back: tuple             # per-child (entry_index, budget) used


def _prune(entries: list[_Entry]) -> list[_Entry]:
    """Keep the Pareto frontier: increasing m => strictly decreasing b."""
    entries.sort(key=lambda e: (e.m, e.b))
    out: list[_Entry] = []
    best_b = np.inf
    for e in entries:
        if e.b < best_b - 1e-12:
            out.append(e)
            best_b = e.b
    return out


def solve_bottleneck(t: Tree, load, k: int, avail=None):
    """Exact lambda-BIC: returns (blue_mask, optimal_bottleneck).

    Exponential only in frontier size (pruned); fine for the evaluation
    scale (trees up to a few hundred nodes, k <= ~16).
    """
    load = np.asarray(load, dtype=np.int64)
    availm = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    sub = t.subtree_loads(load)
    K = k + 1
    # tables[v][i] = Pareto list of _Entry
    tables: list[list[list[_Entry]] | None] = [None] * t.n

    for v in t.topo[::-1]:
        rho = float(t.rho[v])
        send = 1 if sub[v] > 0 else 0
        rows: list[list[_Entry]] = [[] for _ in range(K)]
        kids = t.children[v]
        if not kids:
            for i in range(K):
                red = _Entry(int(load[v]), load[v] * rho, False, ())
                rows[i] = [red]
                if i >= 1 and availm[v]:
                    rows[i].append(_Entry(send, send * rho, True, ()))
                rows[i] = _prune(rows[i])
            tables[v] = rows
            continue
        # fold children one at a time: combo[i] = frontier of
        # (sum m, max b, back chain) using i blue among processed children
        combo: list[list[tuple[int, float, tuple]]] = [
            [(0, 0.0, ())] if i == 0 else [] for i in range(K)]
        for c in kids:
            nxt: list[list[tuple[int, float, tuple]]] = [[] for _ in range(K)]
            for i in range(K):
                for j in range(i + 1):
                    for (m0, b0, back0) in combo[i - j]:
                        for ei, e in enumerate(tables[c][j]):
                            nxt[i].append((m0 + e.m, max(b0, e.b),
                                           back0 + ((ei, j),)))
            # prune each budget row (reuse _Entry machinery)
            for i in range(K):
                es = [_Entry(m, b, False, back) for (m, b, back) in nxt[i]]
                nxt[i] = [(e.m, e.b, e.back) for e in _prune(es)]
            combo = nxt
        for i in range(K):
            out: list[_Entry] = []
            for (m0, b0, back) in combo[i]:
                mr = int(load[v]) + m0
                out.append(_Entry(mr, max(b0, mr * rho), False, back))
            if i >= 1 and availm[v]:
                for (m0, b0, back) in combo[i - 1]:
                    out.append(_Entry(send, max(b0, send * rho), True, back))
            rows[i] = _prune(out)
        tables[v] = rows

    r = t.root
    best = min(tables[r][k], key=lambda e: e.b)

    # traceback
    blue = np.zeros(t.n, bool)
    stack = [(r, best)]
    while stack:
        v, e = stack.pop()
        blue[v] = e.color
        for c, (ei, j) in zip(t.children[v], e.back):
            stack.append((c, tables[c][j][ei]))
    return blue, float(best.b)
