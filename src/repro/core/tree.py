"""Tree network topology for the phi-BIC problem (paper Sec. 2).

Nodes 0..n-1 are switches; the destination server ``d`` is implicit *above*
the root switch ``r``.  Every switch v has exactly one upward edge
``(v, p(v))``; the root's upward edge is ``(r, d)``.  ``rho[v]`` is the
reciprocal link rate of that edge (transmission time per message).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

DEST = -1  # parent id of the root switch (the destination server d)


@dataclasses.dataclass(frozen=True)
class Tree:
    """Immutable rooted tree of switches with per-edge reciprocal rates."""

    parent: np.ndarray  # (n,) int32; parent[root] == DEST
    rho: np.ndarray     # (n,) float64; rho[v] = 1/omega((v, p(v)))

    def __post_init__(self):
        parent = np.asarray(self.parent, dtype=np.int32)
        rho = np.asarray(self.rho, dtype=np.float64)
        object.__setattr__(self, "parent", parent)
        object.__setattr__(self, "rho", rho)
        n = parent.shape[0]
        if rho.shape != (n,):
            raise ValueError(f"rho shape {rho.shape} != ({n},)")
        roots = np.nonzero(parent == DEST)[0]
        if len(roots) != 1:
            raise ValueError(f"tree must have exactly one root, got {roots}")
        if np.any(rho <= 0):
            raise ValueError("rho (reciprocal rates) must be positive")
        object.__setattr__(self, "_root", int(roots[0]))
        # depth (distance from root r; D(r)=0) and validation of acyclicity.
        depth = np.full(n, -1, dtype=np.int32)
        depth[self._root] = 0
        # children adjacency
        order = [self._root]
        kids: list[list[int]] = [[] for _ in range(n)]
        for v in range(n):
            p = parent[v]
            if p != DEST:
                if not (0 <= p < n):
                    raise ValueError(f"bad parent {p} for node {v}")
                kids[p].append(v)
        head = 0
        while head < len(order):
            u = order[head]
            head += 1
            for c in kids[u]:
                depth[c] = depth[u] + 1
                order.append(c)
        if len(order) != n:
            raise ValueError("tree is disconnected or cyclic")
        object.__setattr__(self, "depth", depth)
        object.__setattr__(self, "children", tuple(tuple(k) for k in kids))
        # topological order: root first; reversed() gives leaves-first.
        object.__setattr__(self, "topo", np.asarray(order, dtype=np.int32))
        # pathrho[v]: sum of rho along the full path v -> d.
        pathrho = np.zeros(n, dtype=np.float64)
        for u in order:  # root first: parent already done
            p = parent[u]
            pathrho[u] = rho[u] + (pathrho[p] if p != DEST else 0.0)
        object.__setattr__(self, "pathrho", pathrho)

    # -- basic properties ---------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.parent.shape[0])

    @property
    def root(self) -> int:
        return self._root

    @property
    def height(self) -> int:
        """h(T) = max_v D(v) (paper Sec. 2)."""
        return int(self.depth.max())

    def is_leaf(self, v: int) -> bool:
        return len(self.children[v]) == 0

    @property
    def leaves(self) -> np.ndarray:
        return np.asarray([v for v in range(self.n) if self.is_leaf(v)], np.int32)

    def degree(self, v: int) -> int:
        """Undirected degree in T (children + parent edge)."""
        return len(self.children[v]) + 1  # every switch has an up edge

    def ancestor(self, v: int, ell: int) -> int:
        """A_v^ell: the ancestor at distance ell above v (DEST if past root)."""
        u = v
        for _ in range(ell):
            if u == DEST:
                raise ValueError("walked past destination")
            u = int(self.parent[u])
        return u

    def rho_up(self, v: int, ell: int) -> float:
        """rho(v, A_v^ell): cumulative transmission time of ell hops above v.

        ell may range 0 .. depth[v]+1 (the +1 reaching the destination d).
        """
        if ell == 0:
            return 0.0
        a = self.ancestor(v, ell)
        return float(self.pathrho[v] - (self.pathrho[a] if a != DEST else 0.0))

    def rho_up_table(self, max_ell: int | None = None) -> np.ndarray:
        """Dense table R[v, ell] = rho(v, A_v^ell), inf where ell > depth[v]+1.

        Vectorized ancestor walk: hop ``ell`` adds the up-edge rho of every
        node's current ancestor, all nodes at once (same per-node addition
        order as the scalar walk, so results are bit-identical).
        """
        h = self.height
        m = (h + 2) if max_ell is None else (max_ell + 1)
        n = self.n
        out = np.full((n, m), np.inf, dtype=np.float64)
        out[:, 0] = 0.0
        cur = np.arange(n)              # A_v^{ell-1}
        acc = np.zeros(n, dtype=np.float64)
        for ell in range(1, m):
            alive = cur != DEST
            if not alive.any():
                break
            idx = np.where(alive, cur, 0)
            acc = acc + self.rho[idx]
            out[alive, ell] = acc[alive]
            cur = np.where(alive, self.parent[idx], DEST)
        return out

    def subtree_sizes(self) -> np.ndarray:
        sz = np.ones(self.n, dtype=np.int64)
        for u in self.topo[::-1]:
            p = self.parent[u]
            if p != DEST:
                sz[p] += sz[u]
        return sz

    def subtree_loads(self, load: np.ndarray) -> np.ndarray:
        tl = np.asarray(load, dtype=np.int64).copy()
        for u in self.topo[::-1]:
            p = self.parent[u]
            if p != DEST:
                tl[p] += tl[u]
        return tl


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def bt(n_total: int, rate_scheme: str = "constant") -> Tree:
    """Complete binary tree BT(n_total) per paper Sec. 5.

    ``n_total`` counts *all* nodes including the destination server, so the
    switch tree has n_total - 1 nodes and must be a complete binary tree
    (n_total a power of two). BT(256) -> 255 switches, 128 leaves.
    """
    n = n_total - 1
    if n < 1 or (n & (n + 1)) != 0:
        raise ValueError(f"BT needs n_total a power of 2, got {n_total}")
    parent = np.empty(n, dtype=np.int32)
    parent[0] = DEST
    for v in range(1, n):
        parent[v] = (v - 1) // 2
    t = Tree(parent, np.ones(n))
    return with_rates(t, rate_scheme)


def with_rates(t: Tree, scheme: str) -> Tree:
    """Apply the paper's rate schemes (Sec. 5): constant / linear / exponential.

    Leaf edges have rate 1; rates increase towards the root either by +1 per
    level (linear) or doubling (exponential). Level is measured from the
    deepest leaves: edge (v, p(v)) at tree-depth D(v) has
    level_from_leaf = h - D(v).
    """
    h = t.height
    lvl = h - t.depth  # 0 at deepest leaves, h at root edge... root edge lvl=h
    if scheme == "constant":
        rate = np.ones(t.n)
    elif scheme == "linear":
        rate = 1.0 + lvl
    elif scheme == "exponential":
        rate = np.power(2.0, lvl)
    else:
        raise ValueError(f"unknown rate scheme {scheme!r}")
    return Tree(t.parent, 1.0 / rate)


def rpa(n_total: int, seed: int = 0) -> Tree:
    """Random preferential attachment (scale-free) tree, Appendix B.

    Node 0 is the root switch; each new node attaches to an existing switch
    with probability proportional to its current (undirected) degree.
    """
    n = n_total - 1
    rng = np.random.default_rng(seed)
    parent = np.full(n, DEST, dtype=np.int32)
    deg = np.zeros(n, dtype=np.float64)
    deg[0] = 1.0  # root's edge to d
    for v in range(1, n):
        w = deg[:v] / deg[:v].sum()
        p = int(rng.choice(v, p=w))
        parent[v] = p
        deg[p] += 1.0
        deg[v] = 1.0
    return Tree(parent, np.ones(n))


# ---------------------------------------------------------------------------
# Load distributions (paper Sec. 5: mean 5; uniform [4,6], power-law [1,63])
# ---------------------------------------------------------------------------

def _powerlaw_pmf(alpha: float, lo: int = 1, hi: int = 63) -> np.ndarray:
    x = np.arange(lo, hi + 1, dtype=np.float64)
    p = x ** (-alpha)
    return p / p.sum()


def _calibrate_powerlaw(target_mean: float = 5.0, lo: int = 1, hi: int = 63) -> float:
    """Find alpha such that the truncated power-law mean equals target_mean."""
    x = np.arange(lo, hi + 1, dtype=np.float64)

    def mean(alpha: float) -> float:
        p = _powerlaw_pmf(alpha, lo, hi)
        return float((x * p).sum())

    a_lo, a_hi = 0.0, 5.0  # mean decreases in alpha
    for _ in range(80):
        mid = 0.5 * (a_lo + a_hi)
        if mean(mid) > target_mean:
            a_lo = mid
        else:
            a_hi = mid
    return 0.5 * (a_lo + a_hi)


_POWERLAW_ALPHA = _calibrate_powerlaw()


def sample_load(
    t: Tree,
    dist: str = "uniform",
    seed: int = 0,
    leaves_only: bool = True,
) -> np.ndarray:
    """Sample the network load L (paper Sec. 5 distribution characteristics)."""
    rng = np.random.default_rng(seed)
    load = np.zeros(t.n, dtype=np.int64)
    where = t.leaves if leaves_only else np.arange(t.n)
    m = len(where)
    if dist == "uniform":
        vals = rng.integers(4, 7, size=m)  # {4,5,6}: mean 5
    elif dist == "power-law":
        pmf = _powerlaw_pmf(_POWERLAW_ALPHA)
        vals = rng.choice(np.arange(1, 64), size=m, p=pmf)
    elif dist == "ones":
        vals = np.ones(m, dtype=np.int64)  # Appendix B scale-free setting
    else:
        raise ValueError(f"unknown load distribution {dist!r}")
    load[where] = vals
    return load


def random_tree(n: int, seed: int = 0, max_children: int = 4) -> Tree:
    """Arbitrary random tree + random rates — used by property tests."""
    rng = np.random.default_rng(seed)
    parent = np.full(n, DEST, dtype=np.int32)
    for v in range(1, n):
        parent[v] = int(rng.integers(0, v)) if max_children <= 0 else int(
            rng.integers(max(0, v - 3 * max_children), v)
        )
    rho = rng.uniform(0.1, 3.0, size=n)
    return Tree(parent, rho)
