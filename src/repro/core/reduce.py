"""Reduce-operation simulator (paper Algorithm 1) and utilization cost phi.

Message semantics:
  * a red (non-aggregating) switch forwards every message arriving from its
    children plus L(v) messages of its own servers;
  * a blue (aggregating) switch collapses everything into a single outgoing
    message — but only if its subtree holds any load at all ("the operation
    ends when the destination receives the information from all nodes that
    have strictly positive load"): a zero-load subtree sends nothing.
"""
from __future__ import annotations

import numpy as np

from .tree import DEST, Tree


def messages_up(t: Tree, load: np.ndarray, blue: np.ndarray) -> np.ndarray:
    """msg_e for the upward edge of every switch v (e = (v, p(v)))."""
    load = np.asarray(load, dtype=np.int64)
    blue = np.asarray(blue, dtype=bool)
    sub_load = t.subtree_loads(load)
    msgs = np.zeros(t.n, dtype=np.int64)
    for v in t.topo[::-1]:  # leaves first
        if blue[v]:
            msgs[v] = 1 if sub_load[v] > 0 else 0
        else:
            acc = int(load[v])
            for c in t.children[v]:
                acc += int(msgs[c])
            msgs[v] = acc
    return msgs


def phi(t: Tree, load: np.ndarray, blue: np.ndarray) -> float:
    """Utilization complexity phi(T, L, U) = sum_e msg_e * rho(e) (Eq. 1)."""
    return float((messages_up(t, load, blue) * t.rho).sum())


def agg_width(total: int, scale: float) -> int:
    """Messages a blue switch at capacity scale ``scale`` folds itself.

    A switch whose aggregation plane runs at a fraction ``scale`` of its
    nominal capacity (P4COM-style partial memory/compute loss) folds only
    the *first* ``ceil(total * scale)`` of its ``total`` incoming messages
    — never fewer than one, so it always emits a partial sum — and spills
    the rest raw to its parent. ``scale >= 1`` is the pristine plane
    (everything folds); the ``scale -> 0`` limit folds a single message,
    i.e. the switch degenerates to a forwarder plus a no-op partial.
    """
    total = int(total)
    if total <= 1 or scale >= 1.0:
        return total
    return max(1, int(np.ceil(total * float(scale))))


def messages_up_degraded(t: Tree, load: np.ndarray, blue: np.ndarray,
                         cap_scale: np.ndarray | None = None) -> np.ndarray:
    """Per-edge message counts when blue switches run at reduced capacity.

    ``cap_scale[v]`` is switch v's remaining aggregation-capacity fraction
    (``None`` = all pristine, in which case this is exactly
    :func:`messages_up`). A degraded blue switch with ``w`` incoming
    messages folds ``m = agg_width(w, cap_scale[v])`` of them and sends
    the ``o = w - m`` overflow raw on its own up-edge (``1 + o`` messages
    instead of 1); the overflow is completed at the parent's host, so
    every edge *above* the degraded switch carries its fault-free count.
    """
    msgs = messages_up(t, load, blue)
    if cap_scale is None:
        return msgs
    scale = np.asarray(cap_scale, np.float64)
    if scale.shape != (t.n,):
        raise ValueError(f"cap_scale shape {scale.shape} != ({t.n},)")
    load = np.asarray(load, dtype=np.int64)
    blue = np.asarray(blue, dtype=bool)
    sub_load = t.subtree_loads(load)
    out = msgs.copy()
    for v in range(t.n):
        if blue[v] and sub_load[v] > 0 and scale[v] < 1.0:
            w = int(load[v]) + sum(int(msgs[c]) for c in t.children[v])
            if w > 1:
                out[v] = msgs[v] + (w - agg_width(w, float(scale[v])))
    return out


def phi_degraded(t: Tree, load: np.ndarray, blue: np.ndarray,
                 cap_scale: np.ndarray | None = None) -> float:
    """Utilization of a placement executed at reduced switch capacity:
    phi plus the overflow traffic each degraded blue switch spills one
    hop up. Equals :func:`phi` when ``cap_scale`` is ``None``/all-ones."""
    return float((messages_up_degraded(t, load, blue, cap_scale)
                  * t.rho).sum())


def phi_barrier(t: Tree, load: np.ndarray, blue: np.ndarray) -> float:
    """Alternative characterization via closest blue ancestors (Lemma 4.2).

    phi = sum_{v in U} send(v) * rho(v, p*_v) + sum_{v not in U} L(v) * rho(v, p*_v)

    (send(v) = 1 iff subtree load > 0; equals the paper's ``1`` whenever all
    loads are positive). Used as a cross-check oracle in tests.
    """
    load = np.asarray(load, dtype=np.int64)
    blue = np.asarray(blue, dtype=bool)
    sub_load = t.subtree_loads(load)
    total = 0.0
    for v in range(t.n):
        # distance/time to closest blue ancestor or d
        u = int(t.parent[v])
        acc = float(t.rho[v])
        while u != DEST and not blue[u]:
            acc += float(t.rho[u])
            u = int(t.parent[u])
        if blue[v]:
            total += (1 if sub_load[v] > 0 else 0) * acc
        else:
            total += int(load[v]) * acc
    return total


def all_red(t: Tree) -> np.ndarray:
    return np.zeros(t.n, dtype=bool)


def all_blue(t: Tree) -> np.ndarray:
    return np.ones(t.n, dtype=bool)


def mask_from_set(t: Tree, U) -> np.ndarray:
    m = np.zeros(t.n, dtype=bool)
    for v in U:
        m[int(v)] = True
    return m
