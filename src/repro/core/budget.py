"""Cross-workload budget allocation (paper §8, second open problem).

"The main question there is how to distribute the overall aggregation
capacity available throughout the network to the various workloads being
served. Specifically, every workload might be serviced by a *distinct*
number of aggregation switches (i.e., there need not be a uniform k for
all workloads)."

Given workloads L_1..L_W and a TOTAL budget K, choose per-workload budgets
k_w with sum k_w <= K minimizing total utilization sum_w phi-BIC(T, L_w, k_w).

Approach: each workload's optimal-cost curve c_w(k) is produced by ONE
SOAR-Gather run (the root table row X_r(1, ·) gives the optimum for every
k <= K simultaneously — the DP is incremental in the budget). Greedy
marginal allocation on the savings curves is optimal when every curve is
convex in k (diminishing returns); SOAR curves are monotone but not always
convex, so we run greedy on the *concave envelope* of each savings curve —
this is exact for the relaxed (envelope) problem and, because envelope
break-points are always feasible pure allocations, yields an allocation
whose gap we can bound and test against brute force (tests/test_budget.py).
"""
from __future__ import annotations

import heapq

import numpy as np

from .soar_fast import soar_gather_vectorized
from .tree import Tree


def cost_curve(t: Tree, load, k_max: int, avail=None) -> np.ndarray:
    """c[k] = phi-BIC(T, L, k) for k = 0..k_max — one gather run."""
    X = soar_gather_vectorized(t, load, k_max, avail)
    return np.asarray(X[t.root, 1, : k_max + 1], dtype=np.float64)


def _concave_envelope_gains(c: np.ndarray) -> np.ndarray:
    """Per-unit marginal savings of the concave envelope of (red - c)."""
    s = c[0] - c                       # savings, monotone non-decreasing
    # upper concave envelope via monotone chain on (k, s)
    hull = [(0, s[0])]
    for k in range(1, len(s)):
        while len(hull) >= 2:
            (k1, s1), (k2, s2) = hull[-2], hull[-1]
            if (s2 - s1) * (k - k2) <= (s[k] - s2) * (k2 - k1):
                hull.pop()
            else:
                break
        hull.append((k, s[k]))
    gains = np.zeros(len(s))
    for (k1, s1), (k2, s2) in zip(hull, hull[1:]):
        gains[k1 + 1 : k2 + 1] = (s2 - s1) / (k2 - k1)
    return gains


def allocate_budget(t: Tree, workloads, K: int, avail=None,
                    k_max: int | None = None):
    """Greedy-on-envelopes allocation: returns (budgets, total_cost).

    budgets[w] sums to <= K; total_cost = sum_w c_w(budgets[w]).
    """
    W = len(workloads)
    k_cap = min(K, k_max) if k_max else K
    curves = [cost_curve(t, L, k_cap, avail) for L in workloads]
    gains = [_concave_envelope_gains(c) for c in curves]
    budgets = np.zeros(W, dtype=np.int64)
    heap = [(-gains[w][1], w) for w in range(W) if k_cap >= 1]
    heapq.heapify(heap)
    remaining = K
    while heap and remaining > 0:
        negg, w = heapq.heappop(heap)
        if negg == 0.0:
            break
        budgets[w] += 1
        remaining -= 1
        nxt = budgets[w] + 1
        if nxt <= k_cap:
            heapq.heappush(heap, (-gains[w][nxt], w))
    total = float(sum(c[b] for c, b in zip(curves, budgets)))
    return budgets, total


def brute_allocate(t: Tree, workloads, K: int, avail=None):
    """Exact allocator (enumerate compositions) — small instances only."""
    W = len(workloads)
    curves = [cost_curve(t, L, K, avail) for L in workloads]

    best = (np.inf, None)

    def rec(w, left, acc, picks):
        nonlocal best
        if w == W:
            if acc < best[0]:
                best = (acc, list(picks))
            return
        for k in range(left + 1):
            rec(w + 1, left - k, acc + curves[w][k], picks + [k])

    rec(0, K, 0.0, [])
    return np.asarray(best[1], dtype=np.int64), float(best[0])


def uniform_allocate(t: Tree, workloads, K: int, avail=None):
    """Baseline: the same k = K // W for every workload."""
    W = len(workloads)
    k = K // W
    curves = [cost_curve(t, L, k, avail) for L in workloads]
    budgets = np.full(W, k, dtype=np.int64)
    return budgets, float(sum(c[k] for c in curves))
