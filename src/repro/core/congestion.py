"""Per-link traffic and congestion for multi-tenant placements.

SOAR minimizes each tenant's *total* utilization; when T tenants share one
reduction tree their placements can pile messages onto the same links. The
congestion objective (Segal et al. 2022, *Constrained In-network Computing
with Low Congestion in Datacenter Networks*) is the *max-link* traffic:

    congestion(e) = sum_t msg_e^t        (optionally time-weighted by rho_e)

This module provides the measurement half of that objective:

  * :func:`messages_up_batch` — host-numpy reference: per-tenant
    ``messages_up`` stacked over the batch;
  * :func:`messages_up_forest` — the batched device kernel over the
    level-packed :class:`~repro.core.forest.Forest` layout: a bottom-up
    level-synchronous sweep (one fused gather+sum per level, no scatters)
    that is **bit-identical** to the host reference (pure int32 arithmetic,
    same per-node child sums);
  * :func:`congestion_profile` — per-link totals across tenants.

The iterative re-solve driver that *optimizes* the objective lives in
``repro.engine.congestion``; it calls :func:`messages_up_forest` on the
same Forest it just solved, so the traffic measurement reuses the packed
arrays already on the accelerator.
"""
from __future__ import annotations

import functools
import weakref
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .forest import Forest
from .reduce import messages_up
from .tree import Tree


def messages_up_batch(trees, loads, blues) -> np.ndarray:
    """Host reference: stacked :func:`~repro.core.reduce.messages_up`.

    ``trees``/``loads``/``blues`` are per-tenant sequences; returns the
    ``(T, n)`` int64 per-edge message counts (edge e = (v, parent(v))).
    """
    return np.stack([messages_up(t, L, U)
                     for t, L, U in zip(trees, loads, blues, strict=True)])


def _messages_body(
    pk_kid: jax.Array,     # (B, S, max_c) int32 child slots, sentinel S
    pk_load: jax.Array,    # (B, S) int
    pk_send: jax.Array,    # (B, S) int
    blue_slot: jax.Array,  # (B, S) bool
    *,
    lvl_off: tuple,
    lvl_width: tuple,
    lvl_internal: tuple,
) -> jax.Array:
    """Bottom-up level-synchronous message sweep over the packed layout.

    Mirrors the host recurrence exactly: a blue switch emits ``send(v)``
    (1 iff its subtree holds load), a red switch forwards its own load
    plus every child's messages. Children live one level down, so each
    level is one gather + sum; results land as contiguous level blocks
    (no scatters). Integer arithmetic throughout — bit-identical to
    :func:`messages_up_batch` by construction.

    Plain traceable function returning the ``(B, S)`` *slot-indexed*
    counts (level blocks are contiguous, so the concat IS slot order);
    jitted callers: :func:`_messages_packed` for the node-indexed public
    result, and the device-resident congestion loop, which feeds the
    color sweep's slot-indexed masks straight in and keeps the counts on
    the accelerator.
    """
    B, S, max_c = pk_kid.shape
    h_max = len(lvl_off) - 1
    msgs_lvl: list = [None] * (h_max + 1)
    for d in range(h_max, -1, -1):
        o, W, Wi = lvl_off[d], lvl_width[d], lvl_internal[d]
        if W == 0:                                     # bucketed tail level
            msgs_lvl[d] = jnp.zeros((B, 0), jnp.int32)
            continue
        acc = pk_load[:, o : o + W].astype(jnp.int32)
        if Wi > 0:
            # red child sum: address children level-locally, with a zero
            # appended at index W1 where sentinel (missing) children land.
            o1, W1 = lvl_off[d + 1], lvl_width[d + 1]
            ch = jnp.concatenate(
                [msgs_lvl[d + 1], jnp.zeros((B, 1), jnp.int32)], axis=1)
            kidl = jnp.minimum(pk_kid[:, o : o + Wi] - o1, W1)
            childsum = jnp.take_along_axis(
                ch, kidl.reshape(B, Wi * max_c), axis=1
            ).reshape(B, Wi, max_c).sum(axis=2)
            acc = jnp.concatenate([acc[:, :Wi] + childsum, acc[:, Wi:]],
                                  axis=1)
        msgs_lvl[d] = jnp.where(blue_slot[:, o : o + W],
                                pk_send[:, o : o + W].astype(jnp.int32), acc)
    return jnp.concatenate([m for m in msgs_lvl if m.shape[1]], axis=1)


@functools.partial(jax.jit,
                   static_argnames=("lvl_off", "lvl_width", "lvl_internal"))
def _messages_packed(
    pk_kid: jax.Array,     # (B, S, max_c) int32 child slots, sentinel S
    pk_load: jax.Array,    # (B, S) int
    pk_send: jax.Array,    # (B, S) int
    blue_slot: jax.Array,  # (B, S) bool
    slot_of: jax.Array,    # (B, n_max) int32 node -> slot (S at padding)
    *,
    lvl_off: tuple,
    lvl_width: tuple,
    lvl_internal: tuple,
) -> jax.Array:
    """Jitted :func:`_messages_body`, gathered back to node indexing."""
    B = pk_kid.shape[0]
    flat = _messages_body(pk_kid, pk_load, pk_send, blue_slot,
                          lvl_off=lvl_off, lvl_width=lvl_width,
                          lvl_internal=lvl_internal)
    pad = jnp.concatenate([flat, jnp.zeros((B, 1), jnp.int32)], axis=1)
    return jnp.take_along_axis(pad, slot_of, axis=1)


_MSG_INPUT_CACHE: dict[int, tuple] = {}


def _msg_device_inputs(f: Forest) -> tuple:
    """One host->device upload of the sweep's static arrays per Forest.

    Same discipline (and caveat) as the engine's ``_device_inputs``: keyed
    on Forest identity via weakref, so a driver loop measuring the same
    built Forest every round uploads ``pk_kid``/``pk_load``/``pk_send``/
    ``slot_of`` once, not per call. Built Forests are treated as
    immutable — rebuild instead of mutating in place.
    """
    key = id(f)
    hit = _MSG_INPUT_CACHE.get(key)
    if hit is not None and hit[0]() is f:
        return hit[1]
    inputs = (jnp.asarray(f.pk_kid), jnp.asarray(f.pk_load),
              jnp.asarray(f.pk_send), jnp.asarray(f.slot_of))
    _MSG_INPUT_CACHE[key] = (weakref.ref(f, lambda _, k=key:
                                         _MSG_INPUT_CACHE.pop(k, None)),
                             inputs)
    return inputs


def messages_up_forest(f: Forest, blue: np.ndarray) -> np.ndarray:
    """Batched per-edge message counts on device, node-indexed.

    ``blue``: the ``(B, n_max)`` node-indexed masks exactly as
    :func:`repro.engine.solve_forest` returns them (False at padding).
    Returns ``(B, n_max)`` int64 message counts, zero at padded nodes —
    bit-identical to the host :func:`messages_up_batch` on the real nodes.
    The device sweep accumulates in int32 (jax keeps 64-bit ints only
    under ``jax_enable_x64``), so instances whose total load reaches 2**31
    — beyond any real fleet — are rejected rather than silently wrapped.
    """
    B, n_max = f.mask.shape
    if blue.shape != (B, n_max):
        raise ValueError(f"blue shape {blue.shape} != {(B, n_max)}")
    # no edge carries more messages than its instance's total load
    peak = int(f.pk_load.sum(axis=1).max()) if f.pk_load.size else 0
    if peak >= 2 ** 31:
        raise ValueError(f"total load {peak} overflows the device sweep's "
                         "int32 accumulator; use messages_up_batch")
    # slot-indexed blue: padded slots (slot_node < 0) are never blue
    src = np.where(f.slot_node >= 0, f.slot_node, 0)
    blue_slot = np.take_along_axis(np.asarray(blue, bool), src, axis=1)
    blue_slot &= f.slot_node >= 0
    kid, load, send, slot_of = _msg_device_inputs(f)
    out = _messages_packed(
        kid, load, send, jnp.asarray(blue_slot), slot_of,
        lvl_off=f.lvl_off, lvl_width=f.lvl_width,
        lvl_internal=f.lvl_internal)
    return np.asarray(out, np.int64)


def congestion_profile(msgs: np.ndarray,
                       rho: np.ndarray | None = None) -> np.ndarray:
    """Per-link congestion across tenants: ``sum_t msg_e^t [* rho_e]``.

    ``msgs``: (T, n) per-tenant message counts on a *shared* tree (so link
    e of every tenant is the same physical link). ``rho`` switches from
    message-count congestion (the default, Segal et al.'s objective) to
    time-weighted congestion (transmission seconds per link).
    """
    c = np.asarray(msgs, np.int64).sum(axis=0)
    return c * np.asarray(rho) if rho is not None else c


class FleetMeasurement(NamedTuple):
    """Congestion measurement of T placements on one shared tree."""

    msgs: np.ndarray            # (T, n) per-tenant per-link message counts
    congestion: np.ndarray      # (n,) per-link totals (count or time)
    max_congestion: float
    mean_congestion: float      # mean over links carrying traffic
    costs: np.ndarray           # (T,) per-tenant utilization on t.rho


def measure_fleet(t: Tree, loads, blues,
                  rho_weighted: bool = False) -> FleetMeasurement:
    """Host-side fleet measurement — the single definition of the reported
    congestion statistics. Both the driver's result tail
    (``repro.engine.congestion``) and the orchestrator's post-admission
    re-measure report exactly these semantics: max over all links, mean
    over links that carry traffic, utilization on the *original* rho."""
    msgs = messages_up_batch([t] * len(loads), loads, blues)
    prof = congestion_profile(msgs, t.rho if rho_weighted else None)
    carrying = prof[prof > 0]
    return FleetMeasurement(
        msgs=msgs, congestion=prof,
        max_congestion=float(prof.max()),
        mean_congestion=float(carrying.mean()) if carrying.size else 0.0,
        costs=(msgs * t.rho).sum(axis=1).astype(np.float64))


def max_congestion(t: Tree, loads, blues,
                   rho_weighted: bool = False) -> float:
    """Convenience: max-link congestion of per-tenant placements on ``t``."""
    return measure_fleet(t, loads, blues, rho_weighted).max_congestion


class MultiFleetMeasurement(NamedTuple):
    """Congestion measurement of T placements across N trees + shared core.

    Link ids follow the fleet's global link-id space: tree g's up-links
    occupy ``[link_off[g], link_off[g] + n_g)`` in ``congestion``, the
    shared-core links fill the final ``C`` entries (also broken out as
    ``core_congestion``). ``msgs`` rows are tree-local (tenant t's counts
    on its own tree, zero-padded to the widest tree); ``costs`` stay
    tree-local utilization on each tree's original rho — identical
    semantics to :func:`measure_fleet` for the N=1 fleet.
    """

    msgs: np.ndarray            # (T, max_g n_g) tree-local message counts
    congestion: np.ndarray      # (sum n_g + C,) global per-link profile
    core_congestion: np.ndarray  # (C,)
    max_congestion: float
    mean_congestion: float      # mean over links carrying traffic
    costs: np.ndarray           # (T,) per-tenant utilization on own tree
    link_off: np.ndarray        # (N,) global segment start per tree


def measure_fleet_multi(trees, tree_of, loads, blues, core_rho=None,
                        core_path=None,
                        rho_weighted: bool = False) -> MultiFleetMeasurement:
    """Host-side measurement for a multi-tree fleet sharing a core.

    ``trees``: the N distinct trees; ``tree_of[t]`` names tenant t's tree;
    ``core_rho`` (C,) / ``core_path`` (per tree, core link ids crossed)
    describe the shared core — a tenant's root-crossing messages (the
    count on its root's up-edge) transit every core link on its tree's
    path, which is where tenants on different trees meet. Congestion on a
    core link is the sum of those root counts over the tenants crossing
    it (times ``core_rho`` when ``rho_weighted``). For ``N=1, C=0`` this
    reduces exactly to :func:`measure_fleet` — same sums, same casts.
    """
    trees = list(trees)
    tid = np.asarray(list(tree_of), np.int64)
    T = tid.size
    crho = (np.zeros(0, np.float64) if core_rho is None
            else np.asarray(core_rho, np.float64))
    C = crho.size
    path = (tuple(() for _ in trees) if core_path is None
            else tuple(tuple(int(c) for c in p) for p in core_path))
    tree_n = np.asarray([t.n for t in trees], np.int64)
    link_off = np.concatenate([[0], np.cumsum(tree_n)[:-1]]).astype(np.int64)
    n_big = int(tree_n.max())
    msgs = np.zeros((T, n_big), np.int64)
    costs = np.zeros(T, np.float64)
    for t in range(T):
        g = int(tid[t])
        tr = trees[g]
        m = messages_up(tr, loads[t], blues[t])
        msgs[t, : tr.n] = m
        costs[t] = (m * tr.rho).sum()
    segs = []
    for g, tr in enumerate(trees):
        rows = msgs[tid == g][:, : tr.n]
        prof_g = congestion_profile(rows, tr.rho if rho_weighted else None)
        segs.append(prof_g)
    root_msgs = np.asarray(
        [msgs[t, trees[int(tid[t])].root] for t in range(T)], np.int64)
    core = np.zeros(C, np.float64 if rho_weighted else np.int64)
    for c in range(C):
        crossing = np.asarray([c in path[int(tid[t])] for t in range(T)])
        cnt = root_msgs[crossing].sum()
        core[c] = cnt * crho[c] if rho_weighted else cnt
    prof = np.concatenate(segs + [core]) if C else np.concatenate(segs)
    carrying = prof[prof > 0]
    return MultiFleetMeasurement(
        msgs=msgs, congestion=prof, core_congestion=core,
        max_congestion=float(prof.max()),
        mean_congestion=float(carrying.mean()) if carrying.size else 0.0,
        costs=costs, link_off=link_off)
