"""Brute-force phi-BIC oracle for tests and small-scale validation."""
from __future__ import annotations

import itertools

import numpy as np

from .reduce import mask_from_set, phi
from .tree import Tree


def brute_force(
    t: Tree,
    load: np.ndarray,
    k: int,
    avail: np.ndarray | None = None,
    exactly: bool = False,
) -> tuple[np.ndarray, float]:
    """Minimize phi over all subsets U of available switches with |U| <= k.

    Theta(n^k) — only for small instances (tests / motivating examples).
    """
    avail = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    cand = np.nonzero(avail)[0]
    sizes = [min(k, len(cand))] if exactly else range(min(k, len(cand)) + 1)
    best_mask, best_cost = None, np.inf
    for size in sizes:
        for combo in itertools.combinations(cand, size):
            m = mask_from_set(t, combo)
            c = phi(t, load, m)
            if c < best_cost:
                best_cost, best_mask = c, m
    assert best_mask is not None
    return best_mask, float(best_cost)
