"""Padded batch representation of many phi-BIC instances (a *forest*).

The multi-tenant setting (paper Sec. 5.2) solves one placement instance per
workload; a production engine solves B of them at once. ``Forest`` stacks B
trees of varying shape into dense ``(B, n_max)`` node-indexed arrays with
validity masks, plus a **level-packed slot layout** that the batched JAX
gather in ``repro.engine`` consumes:

  * slots are grouped by depth — every level is one contiguous block, so
    the level-synchronous sweep writes its results with *static* slice
    updates instead of scatters (the difference between a fused memcpy and
    a general scatter op on CPU/TPU);
  * within a level block, internal nodes come first and leaves last: the
    expensive child-fold (the mCost tropical convolution) only runs over
    the internal sub-block, leaves are pure elementwise;
  * missing children point at an *identity* slot (index ``n_slots``) whose
    table is all zeros — for monotone (at-most-k) DP tables the all-zeros
    vector is a min-plus identity, so folding a missing child is a no-op;
  * padded slots inside a block fold only identities and carry zero
    load / BIG rho, so their garbage stays finite and is never read.

Everything here is host-side numpy. Per-tree structure (children matrix,
depth buckets, rho-up table) is cached on the tree object's identity, so a
fleet reusing one topology — the common serving pattern — pays the packing
cost once. Batches of *similar* shapes share one compiled executable in
the engine (the jit key is the packed layout + ``k``), so group instances
by size when throughput matters.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Sequence

import numpy as np

from .tree import DEST, Tree


@dataclasses.dataclass(frozen=True)
class _TreeStruct:
    """Load-independent per-tree arrays (cached by tree identity)."""

    max_c: int
    kid: np.ndarray                 # (n, max(max_c, 1)) int32; -1 sentinel
    rho_up: np.ndarray              # (n, height+2) float64; inf invalid
    internal: tuple[np.ndarray, ...]  # node ids with children, per depth
    leaf: tuple[np.ndarray, ...]      # leaf node ids, per depth
    sub: np.ndarray                 # (n,) int64 subtree sizes
    ni: tuple[int, ...]             # len(internal[d]) per depth
    nl: tuple[int, ...]             # len(leaf[d]) per depth
    submax: tuple[int, ...]         # max subtree size at depth d


_STRUCT_CACHE: dict[int, tuple] = {}


def _tree_struct(t: Tree) -> _TreeStruct:
    key = id(t)
    hit = _STRUCT_CACHE.get(key)
    if hit is not None and hit[0]() is t:
        return hit[1]
    n, h = t.n, t.height
    max_c = max((len(t.children[v]) for v in range(n)), default=0)
    kid = np.full((n, max(max_c, 1)), -1, np.int32)
    internal: list[list[int]] = [[] for _ in range(h + 1)]
    leaf: list[list[int]] = [[] for _ in range(h + 1)]
    for v in range(n):
        ch = t.children[v]
        if ch:
            kid[v, : len(ch)] = ch
            internal[t.depth[v]].append(v)
        else:
            leaf[t.depth[v]].append(v)
    sub = t.subtree_sizes()
    s = _TreeStruct(
        max_c=max_c, kid=kid, rho_up=t.rho_up_table(),
        internal=tuple(np.asarray(l, np.int32) for l in internal),
        leaf=tuple(np.asarray(l, np.int32) for l in leaf),
        sub=sub,
        ni=tuple(len(l) for l in internal),
        nl=tuple(len(l) for l in leaf),
        submax=tuple(
            int(sub[internal[d] + leaf[d]].max())
            if internal[d] or leaf[d] else 0
            for d in range(h + 1)))
    _STRUCT_CACHE[key] = (weakref.ref(t, lambda _, k=key:
                                      _STRUCT_CACHE.pop(k, None)), s)
    return s


@dataclasses.dataclass(frozen=True)
class Forest:
    """B phi-BIC instances padded into dense arrays (see module docstring)."""

    # -- node-indexed (original per-tree node ids, padded to n_max) ----------
    trees: tuple[Tree, ...]        # originals (for unpacking / debugging)
    parent: np.ndarray             # (B, n_max) int32; -1 root, -2 padding
    rho: np.ndarray                # (B, n_max) float64; 1.0 padding
    load: np.ndarray               # (B, n_max) int64; 0 padding
    avail: np.ndarray              # (B, n_max) bool; False padding
    mask: np.ndarray               # (B, n_max) bool; True at real nodes
    depth: np.ndarray              # (B, n_max) int32; -1 padding
    root: np.ndarray               # (B,) int32
    n: np.ndarray                  # (B,) int64 — real node counts
    height: np.ndarray             # (B,) int32
    kid: np.ndarray                # (B, n_max, max_c) int32; sentinel n_max
    rho_up: np.ndarray             # (B, n_max, h_max+2) float64; inf invalid
    send: np.ndarray               # (B, n_max) int64; 1 iff subtree load > 0
    sub_size: np.ndarray           # (B, n_max) int64 subtree sizes; 0 padding
    levels: tuple[np.ndarray, ...]  # levels[d]: (B, W_d) int32 node ids at
                                    # depth d, padded with n_max
    # -- level-packed (slot-indexed) layout for the batched gather ----------
    slot_of: np.ndarray            # (B, n_max) int32 node -> slot; n_slots pad
    slot_node: np.ndarray          # (B, n_slots) int32 slot -> node; -1 pad
    pk_kid: np.ndarray             # (B, n_slots, max_c) int32 child slots;
                                   #   sentinel n_slots (the identity slot)
    pk_par: np.ndarray             # (B, n_slots) int32: parent's index
                                   #   *within its own level block* (0 for
                                   #   roots/padding) — the on-device color
                                   #   gathers its budget from here
    pk_cidx: np.ndarray            # (B, n_slots) int32: this slot's index in
                                   #   its parent's child list (0 roots/pad)
    pk_load: np.ndarray            # (B, n_slots) int64
    pk_send: np.ndarray            # (B, n_slots) int64
    pk_avail: np.ndarray           # (B, n_slots) bool
    pk_rho_up: np.ndarray          # (B, n_slots, h_max+2) float64; inf pad
    lvl_off: tuple[int, ...]       # level d block = slots [lvl_off[d],
    lvl_width: tuple[int, ...]     #   lvl_off[d] + lvl_width[d])
    lvl_internal: tuple[int, ...]  # first lvl_internal[d] slots of the block
                                   #   are internal nodes, the rest leaves
    lvl_sub: tuple[int, ...]       # max subtree size of any node at level d
                                   #   (static knapsack bound: a level-d table
                                   #   never needs more than min(k, lvl_sub[d])
                                   #   + 1 budget columns)

    @property
    def batch(self) -> int:
        return len(self.trees)

    @property
    def n_max(self) -> int:
        return int(self.parent.shape[1])

    @property
    def n_slots(self) -> int:
        return int(self.slot_node.shape[1])

    @property
    def h_max(self) -> int:
        return int(self.rho_up.shape[2] - 2)

    @property
    def max_children(self) -> int:
        return int(self.kid.shape[2])


def _bucket_up(x: int) -> int:
    """Round up to the next power of two (0 and 1 are their own buckets)."""
    return x if x <= 1 else 1 << (x - 1).bit_length()


# jit-cache telemetry: how many forests were packed, and how many *distinct*
# compiled layouts those forests map to (see :func:`layout_key`).
_LAYOUTS_SEEN: set[tuple] = set()
_FORESTS_BUILT: int = 0


def layout_key(f: Forest) -> tuple:
    """The static part of the engine's jit key for this forest.

    Two forests with equal layout keys (and equal budget k / dtype / flags)
    reuse one compiled executable in ``repro.engine``.
    """
    return (f.batch, f.n_max, f.n_slots, f.h_max, f.max_children,
            f.lvl_off, f.lvl_width, f.lvl_internal, f.lvl_sub)


def layout_stats() -> dict:
    """Packing-side cache telemetry: forests built vs distinct jit layouts."""
    return {"forests_built": _FORESTS_BUILT,
            "distinct_layouts": len(_LAYOUTS_SEEN)}


def build_forest(
    trees: Sequence[Tree],
    loads: Sequence[np.ndarray],
    avail: Sequence[np.ndarray] | None = None,
    *,
    bucket: bool = True,
) -> Forest:
    """Stack B (tree, load[, avail]) instances into one padded Forest.

    ``bucket=True`` (default) rounds the layout dimensions that feed the
    engine's jit key — per-level internal/leaf widths, ``max_children``,
    the per-level subtree-size caps, and ``h_max`` (to the next even
    height) — up to bucket boundaries (powers of two). Ragged multi-tenant
    batches whose exact shapes differ then collapse onto a handful of
    compiled executables instead of recompiling per layout; the extra slots
    are ordinary padded slots (identity children, zero load) that the
    sweep already tolerates. ``bucket=False`` packs exact shapes.
    """
    if len(trees) == 0:
        raise ValueError("empty forest")
    if len(loads) != len(trees):
        raise ValueError(f"{len(loads)} loads for {len(trees)} trees")
    if avail is not None and len(avail) != len(trees):
        raise ValueError(f"{len(avail)} avail masks for {len(trees)} trees")
    B = len(trees)
    structs = [_tree_struct(t) for t in trees]
    n_max = max(t.n for t in trees)
    h_max = max(t.height for t in trees)
    max_c = max(max(s.max_c for s in structs), 1)
    if bucket:
        n_max = _bucket_up(n_max)
        h_max += h_max & 1           # next even height
        max_c = _bucket_up(max_c)
    H2 = h_max + 2

    parent = np.full((B, n_max), -2, np.int32)
    rho = np.ones((B, n_max), np.float64)
    load_a = np.zeros((B, n_max), np.int64)
    avail_a = np.zeros((B, n_max), bool)
    mask = np.zeros((B, n_max), bool)
    depth = np.full((B, n_max), -1, np.int32)
    root = np.zeros(B, np.int32)
    nn = np.zeros(B, np.int64)
    height = np.zeros(B, np.int32)
    kid = np.full((B, n_max, max_c), n_max, np.int32)   # identity sentinel
    rho_up = np.full((B, n_max, H2), np.inf, np.float64)
    sub_size = np.zeros((B, n_max), np.int64)

    for b, (t, s) in enumerate(zip(trees, structs)):
        n = t.n
        L = np.asarray(loads[b], np.int64)
        if L.shape != (n,):
            raise ValueError(f"load {b} shape {L.shape} != ({n},)")
        parent[b, :n] = t.parent
        rho[b, :n] = t.rho
        load_a[b, :n] = L
        avail_a[b, :n] = (np.ones(n, bool) if avail is None or avail[b] is None
                          else np.asarray(avail[b], bool))
        mask[b, :n] = True
        depth[b, :n] = t.depth
        root[b] = t.root
        nn[b] = n
        height[b] = t.height
        mc = s.kid.shape[1]
        kid[b, :n, :mc] = np.where(s.kid >= 0, s.kid, n_max)
        rho_up[b, :n, : t.height + 2] = s.rho_up
        sub_size[b, :n] = s.sub

    heights = [int(h) for h in height]
    levels = []
    for d in range(h_max + 1):
        W = max(max((s.ni[d] + s.nl[d] if d <= h else 0
                     for h, s in zip(heights, structs)), default=0), 1)
        lvl = np.full((B, W), n_max, np.int32)
        for b, (h, s) in enumerate(zip(heights, structs)):
            if d > h:
                continue
            ni = s.ni[d]
            lvl[b, :ni] = s.internal[d]
            lvl[b, ni : ni + s.nl[d]] = s.leaf[d]
        levels.append(lvl)

    # send(v) = 1 iff subtree load positive: bottom-up level sweep, batched
    sub = load_a.copy()
    for d in range(h_max, 0, -1):
        nd = levels[d]
        bv, wv = np.nonzero(nd < n_max)
        vv = nd[bv, wv]
        np.add.at(sub, (bv, parent[bv, vv]), sub[bv, vv])
    send = (sub > 0).astype(np.int64)

    # ---- level-packed slot layout -----------------------------------------
    lvl_off, lvl_width, lvl_internal, lvl_sub = [], [], [], []
    S = 0
    for d in range(h_max + 1):
        wi = max((s.ni[d] for h, s in zip(heights, structs) if d <= h),
                 default=0)
        wl = max((s.nl[d] for h, s in zip(heights, structs) if d <= h),
                 default=0)
        sub_d = max((s.submax[d] for h, s in zip(heights, structs)
                     if d <= h), default=0)
        if bucket:
            wi, wl, sub_d = _bucket_up(wi), _bucket_up(wl), _bucket_up(sub_d)
        lvl_off.append(S)
        lvl_internal.append(wi)
        lvl_width.append(wi + wl)
        lvl_sub.append(sub_d)
        S += wi + wl
    slot_of = np.full((B, n_max), S, np.int32)
    slot_node = np.full((B, S), -1, np.int32)
    for b, (h, s) in enumerate(zip(heights, structs)):
        for d in range(h + 1):
            o, wi = lvl_off[d], lvl_internal[d]
            vi, vl = s.internal[d], s.leaf[d]
            slot_of[b, vi] = o + np.arange(len(vi), dtype=np.int32)
            slot_node[b, o : o + len(vi)] = vi
            slot_of[b, vl] = o + wi + np.arange(len(vl), dtype=np.int32)
            slot_node[b, o + wi : o + wi + len(vl)] = vl
    real = slot_node >= 0
    src = np.where(real, slot_node, 0)
    bix = np.arange(B)[:, None]
    pk_load = np.where(real, load_a[bix, src], 0)
    pk_send = np.where(real, send[bix, src], 0)
    pk_avail = np.where(real, avail_a[bix, src], False)
    pk_rho_up = np.where(real[:, :, None], rho_up[bix, src], np.inf)
    ch = kid[bix, src]                                  # (B, S, max_c)
    ch_slot = np.where(
        ch < n_max,
        slot_of[bix[:, :, None], np.minimum(ch, n_max - 1)], S)
    pk_kid = np.where(real[:, :, None], ch_slot, S).astype(np.int32)

    # inverse child pointers: each slot's parent position (local to the
    # parent's level block) and its own index in the parent's child list —
    # the top-down color sweep *gathers* its budget/distance through these
    # instead of scattering parent -> child (scatter-free jit graphs).
    off_of_slot = np.zeros(S, np.int64)
    for d in range(h_max + 1):
        off_of_slot[lvl_off[d] : lvl_off[d] + lvl_width[d]] = lvl_off[d]
    pk_par = np.zeros((B, S), np.int32)
    pk_cidx = np.zeros((B, S), np.int32)
    bs, ss, ms = np.nonzero(pk_kid < S)
    cs = pk_kid[bs, ss, ms]
    pk_par[bs, cs] = (ss - off_of_slot[ss]).astype(np.int32)
    pk_cidx[bs, cs] = ms.astype(np.int32)

    f = Forest(trees=tuple(trees), parent=parent, rho=rho, load=load_a,
               avail=avail_a, mask=mask, depth=depth, root=root, n=nn,
               height=height, kid=kid, rho_up=rho_up, send=send,
               sub_size=sub_size, levels=tuple(levels),
               slot_of=slot_of, slot_node=slot_node, pk_kid=pk_kid,
               pk_par=pk_par, pk_cidx=pk_cidx,
               pk_load=pk_load, pk_send=pk_send, pk_avail=pk_avail,
               pk_rho_up=pk_rho_up, lvl_off=tuple(lvl_off),
               lvl_width=tuple(lvl_width),
               lvl_internal=tuple(lvl_internal), lvl_sub=tuple(lvl_sub))
    global _FORESTS_BUILT
    _FORESTS_BUILT += 1
    _LAYOUTS_SEEN.add(layout_key(f))
    return f


@dataclasses.dataclass(frozen=True)
class FleetLayout:
    """Per-tree segment + shared-link index maps for a multi-tree forest.

    ``build_fleet_forest`` packs T tenant instances — tenant t living on
    tree ``tree_of[t]`` — through the ordinary :func:`build_forest` path
    (a single-tree fleet therefore produces a bit-identical ``Forest`` to
    today's ``build_forest``), and this side table records how the
    instances map back onto the fleet's **global link-id space**: tree g's
    switch up-links occupy ``[link_off[g], link_off[g] + tree_n[g])`` and
    the C shared-core links occupy ``[core_offset, core_offset + C)``.
    """

    tree_of: np.ndarray            # (T,) int32 tenant -> tree index
    n_trees: int
    rep: np.ndarray                # (N,) int64 first tenant on each tree —
                                   #   that batch row carries the tree's
                                   #   canonical layout (slot_of etc.)
    tree_n: np.ndarray             # (N,) int64 real node count per tree
    link_off: np.ndarray           # (N,) int64 global-link segment starts
    core_offset: int               # first global id of the core segment
    core_rho: np.ndarray           # (C,) float64; C may be 0
    core_path: tuple[tuple[int, ...], ...]  # per tree: core links crossed
    core_inc: np.ndarray           # (T, C) bool — tenant t crosses core c

    @property
    def n_core(self) -> int:
        return int(self.core_rho.size)

    @property
    def n_links(self) -> int:
        return self.core_offset + self.n_core


def build_fleet_forest(
    trees: Sequence[Tree],
    loads: Sequence[np.ndarray],
    tree_of: Sequence[int],
    avail: Sequence[np.ndarray] | None = None,
    *,
    core_rho: np.ndarray | None = None,
    core_path: Sequence[Sequence[int]] | None = None,
    bucket: bool = True,
) -> tuple[Forest, FleetLayout]:
    """Pack T tenants living on N distinct trees into one Forest + layout.

    ``trees`` holds the N *distinct* tree objects; ``tree_of[t]`` names
    tenant t's tree. The Forest itself is built by replicating each
    tenant's tree into the batch — exactly ``build_forest([trees[g] for g
    in tree_of], ...)`` — so for ``tree_of == [0]*T`` the packed layout is
    bit-identical to the single-tree call it refactors. Every tree must
    carry at least one tenant (the per-tree congestion profile needs a
    representative batch row for its layout).
    """
    N = len(trees)
    if N == 0:
        raise ValueError("empty fleet")
    tid = np.asarray(list(tree_of), np.int32)
    T = tid.size
    if T == 0:
        raise ValueError("no tenants")
    if len(loads) != T:
        raise ValueError(f"{len(loads)} loads for {T} tenants")
    if tid.min() < 0 or tid.max() >= N:
        raise ValueError(f"tree_of entries must lie in [0, {N})")
    rep = np.full(N, -1, np.int64)
    for t in range(T - 1, -1, -1):
        rep[tid[t]] = t
    if (rep < 0).any():
        empty = [int(g) for g in np.nonzero(rep < 0)[0]]
        raise ValueError(f"trees {empty} carry no tenant — every fleet "
                         f"tree needs at least one")
    tree_n = np.asarray([t.n for t in trees], np.int64)
    link_off = np.concatenate([[0], np.cumsum(tree_n)[:-1]])
    core_offset = int(tree_n.sum())
    crho = (np.zeros(0, np.float64) if core_rho is None
            else np.asarray(core_rho, np.float64))
    C = crho.size
    if crho.ndim != 1:
        raise ValueError(f"core_rho must be 1-D, got shape {crho.shape}")
    path = (tuple(() for _ in range(N)) if core_path is None
            else tuple(tuple(int(c) for c in p) for p in core_path))
    if len(path) != N:
        raise ValueError(f"{len(path)} core paths for {N} trees")
    core_inc = np.zeros((T, C), bool)
    for g, p in enumerate(path):
        for c in p:
            if not 0 <= c < C:
                raise ValueError(f"core link {c} on tree {g}'s path out of "
                                 f"range [0, {C})")
        core_inc[tid == g] = np.isin(np.arange(C), list(p))
    f = build_forest([trees[g] for g in tid], list(loads), avail,
                     bucket=bucket)
    lay = FleetLayout(tree_of=tid, n_trees=N, rep=rep, tree_n=tree_n,
                      link_off=link_off.astype(np.int64),
                      core_offset=core_offset, core_rho=crho,
                      core_path=path, core_inc=core_inc)
    return f, lay
