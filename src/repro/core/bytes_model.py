"""Byte-complexity models for the WC and PS use cases (paper Sec. 5.3).

The utilization complexity counts *messages*; the byte complexity weighs each
message by its size, which grows under aggregation for non-fixed-size
functions (word-count dictionaries) and stays near-constant for others
(dropout-sparsified gradients).

Message-size model: a message aggregated over a set S of servers has expected
size ``size_fn(|S|)`` — the expected number of distinct keys in the union of
the servers' key sets:

* WC: each server holds ``words_per_server`` iid Zipf(s) draws over a
  ``vocab``-word corpus; E[distinct | T draws] = sum_w 1 - (1 - p_w)^T.
  Calibrated to the paper's dump: 54M total words, 800K unique.
* PS: gradient over ``features`` dims with dropout rate q: a server holds each
  key w.p. (1-q); union over s servers has features * (1 - q^s) keys.

A red switch forwards messages unchanged; a blue switch merges everything
below it into one message whose size is size_fn(#servers below). The byte
complexity weighs bytes by rho(e) (equal to plain byte counts at unit rates,
which is the paper's Fig. 8 setting).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable

import numpy as np

from .tree import Tree


# ---------------------------------------------------------------------------
# Use-case message-size functions
# ---------------------------------------------------------------------------

class WordCountModel:
    """Zipf corpus expected-distinct-count size function (WC use case)."""

    def __init__(
        self,
        total_words: int = 54_000_000,
        vocab: int = 800_000,
        zipf_s: float = 1.07,
        n_servers: int = 640,
        bytes_per_kv: int = 12,  # word hash + count
    ):
        self.words_per_server = total_words / n_servers
        self.bytes_per_kv = bytes_per_kv
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = ranks ** (-zipf_s)
        self._log1mp = np.log1p(-(p / p.sum()))
        self._cache: dict[int, float] = {}

    def size(self, n_servers_in_msg: int) -> float:
        """Expected bytes of a message aggregated over n servers."""
        n = int(n_servers_in_msg)
        if n not in self._cache:
            draws = self.words_per_server * n
            distinct = float((1.0 - np.exp(self._log1mp * draws)).sum())
            self._cache[n] = distinct * self.bytes_per_kv
        return self._cache[n]


class ParameterServerModel:
    """Dropout-sparsified gradient size function (PS use case)."""

    def __init__(
        self,
        features: int = 10_000,
        dropout: float = 0.5,
        bytes_per_kv: int = 8,  # index + value
    ):
        self.features = features
        self.keep = 1.0 - dropout
        self.bytes_per_kv = bytes_per_kv

    def size(self, n_servers_in_msg: int) -> float:
        n = int(n_servers_in_msg)
        miss = (1.0 - self.keep) ** n
        return self.features * (1.0 - miss) * self.bytes_per_kv


# ---------------------------------------------------------------------------
# Byte-complexity simulator
# ---------------------------------------------------------------------------

def byte_complexity(
    t: Tree,
    load: np.ndarray,
    blue: np.ndarray,
    size_fn: Callable[[int], float],
    weight_by_rho: bool = True,
) -> float:
    """Total bytes (optionally x rho) sent over all links during Reduce.

    Tracks, per upward edge, a multiset of messages keyed by the number of
    servers already aggregated into each message (sizes only depend on that).
    """
    load = np.asarray(load, dtype=np.int64)
    blue = np.asarray(blue, dtype=bool)
    sub_servers = t.subtree_loads(load)
    # outgoing[v]: dict {servers_in_message: count}
    outgoing: list[dict[int, int] | None] = [None] * t.n
    total = 0.0
    for v in t.topo[::-1]:
        if blue[v]:
            msgs = {int(sub_servers[v]): 1} if sub_servers[v] > 0 else {}
        else:
            msgs = {}
            if load[v] > 0:
                msgs[1] = int(load[v])
            for c in t.children[v]:
                for sc, cnt in outgoing[c].items():  # type: ignore[union-attr]
                    msgs[sc] = msgs.get(sc, 0) + cnt
        outgoing[v] = msgs
        w = float(t.rho[v]) if weight_by_rho else 1.0
        total += w * sum(size_fn(sc) * cnt for sc, cnt in msgs.items())
    return total
