"""Tropical (min-plus) convolution — the SOAR-Gather budget-split primitive.

The mCost inner loop of Algorithm 3 (lines 30-34) is, for every (node, ell)
pair, the min-plus convolution of two monotone budget vectors:

    C[r, i] = min_{0 <= j <= i}  A[r, i-j] + B[r, j]

This module is the single numpy reference used by both the faithful DP
(``soar.py``) and the level-synchronous vectorized gather (``soar_fast.py``).
The accelerator counterparts live in ``repro.kernels.minplus`` (Pallas TPU
kernel + jnp oracle) and ``repro.engine.batched`` (fused jnp CPU path); all
of them implement this exact contract.
"""
from __future__ import annotations

import numpy as np

#: Finite +inf stand-in shared by every accelerated min-plus path (the
#: engine's fused jnp fold, the Pallas kernels and their interpret-mode
#: oracles). Padded/invalid slots must hold a *finite* sentinel so that
#: ``0 * pad`` stays finite (``0 * inf`` is NaN and would poison the min
#: reductions); 1e18 is exactly representable in float32 and far above any
#: reachable utilization. Host float64 references keep using ``np.inf`` —
#: they never multiply a pad by zero.
BIG = 1e18


def minplus(A: np.ndarray, B: np.ndarray, out_w: int | None = None) -> np.ndarray:
    """Row-wise min-plus convolution. A: (L, Wa), B: (L, Wb) -> (L, out_w).

    Y[l, i] = min_{0<=j<=i} A[l, i-j] + B[l, j].

    With monotone (at-most-budget) operands, truncating to ``out_w``
    columns is exact — the subtree-budget cap optimization.
    """
    A = np.atleast_2d(A)
    B = np.atleast_2d(B)
    L, Wa = A.shape
    Wb = B.shape[1]
    W = (Wa + Wb - 1) if out_w is None else min(out_w, Wa + Wb - 1)
    Y = np.full((L, W), np.inf)
    for j in range(min(Wb, W)):
        seg = min(Wa, W - j)
        np.minimum(Y[:, j : j + seg], A[:, :seg] + B[:, j : j + 1],
                   out=Y[:, j : j + seg])
    return Y


def minplus_batch(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Batched square min-plus convolution: (B, K) x (B, K) -> (B, K).

    Same recurrence as :func:`minplus` restricted to equal operand widths
    and output truncated to K (the at-most-k budget table width).
    """
    Bn, K = A.shape
    Y = np.full((Bn, K), np.inf)
    for j in range(K):
        np.minimum(Y[:, j:], A[:, : K - j] + B[:, j : j + 1], out=Y[:, j:])
    return Y
