"""Online multi-workload aggregation-switch allocation (paper Sec. 5.2).

Workloads L_0, L_1, ... arrive online; each is allocated at most k blue
switches before the next arrives. Every switch s has an aggregation capacity
a(s) bounding the number of workloads it can serve; the available set for
workload t is Lambda_t = { s : a_t(s) > 0 }.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import numpy as np

from . import baselines
from .reduce import all_red, phi
from .soar import soar
from .soar_fast import soar_fast
from .tree import Tree


@dataclasses.dataclass
class OnlineResult:
    picks: list[np.ndarray]        # blue mask per workload
    costs: np.ndarray              # phi per workload
    red_costs: np.ndarray          # all-red phi per workload (normalizer)
    residual_capacity: np.ndarray  # a(s) after the full sequence

    @property
    def normalized(self) -> np.ndarray:
        """Cumulative utilization ratio vs all-red after each workload."""
        return np.cumsum(self.costs) / np.cumsum(self.red_costs)


def _strategy_fn(name: str) -> Callable:
    if name == "soar":
        return lambda t, load, k, avail, seed: soar_fast(t, load, k, avail=avail).blue
    fn = baselines.STRATEGIES[name]
    return lambda t, load, k, avail, seed: fn(t, load, k, avail=avail, seed=seed)


def online_allocate(
    t: Tree,
    workloads: Sequence[np.ndarray],
    k: int,
    capacity: int,
    strategy: str = "soar",
    seed: int = 0,
) -> OnlineResult:
    fn = _strategy_fn(strategy)
    a = np.full(t.n, capacity, dtype=np.int64)
    picks, costs, red_costs = [], [], []
    for i, load in enumerate(workloads):
        avail = a > 0
        blue = fn(t, load, k, avail, seed + i)
        blue = blue & avail  # defensive: never exceed capacity
        a[blue] -= 1
        picks.append(blue)
        costs.append(phi(t, load, blue))
        red_costs.append(phi(t, load, all_red(t)))
    return OnlineResult(
        picks=picks,
        costs=np.asarray(costs),
        red_costs=np.asarray(red_costs),
        residual_capacity=a,
    )


def workload_stream(
    t: Tree, n_workloads: int, seed: int = 0
) -> list[np.ndarray]:
    """Paper Sec. 5.2: each workload drawn from uniform or power-law w.p. 1/2."""
    from .tree import sample_load

    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_workloads):
        dist = "uniform" if rng.random() < 0.5 else "power-law"
        out.append(sample_load(t, dist, seed=int(rng.integers(2**31))))
    return out
