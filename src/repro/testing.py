"""Hypothesis compatibility layer for the property tests.

``from repro.testing import given, settings, st`` resolves to the real
Hypothesis when it is installed (the ``[test]`` extra pins it; CI always
has it). In minimal environments without Hypothesis the same names fall
back to a tiny seeded random-sampling harness implementing the subset the
test-suite uses — ``st.integers`` / ``st.floats`` / ``st.booleans`` /
``st.composite``, ``@given`` with positional strategies, and
``@settings(max_examples=..., deadline=...)`` — so collection never breaks
and the invariants still get fuzzed (without shrinking or the database;
install Hypothesis for the real thing).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampling rule: ``example(rng) -> value``."""

        def __init__(self, sample):
            self._sample = sample

        def example(self, rng: random.Random):
            return self._sample(rng)

    class _FallbackStrategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def composite(fn):
            def builder(*args, **kwargs):
                def sample(rng):
                    return fn(lambda s: s.example(rng), *args, **kwargs)
                return _Strategy(sample)
            return builder

    st = _FallbackStrategies()

    _DEFAULT_MAX_EXAMPLES = 30

    def given(*strategies):
        def deco(test):
            # NB: deliberately no functools.wraps — pytest must see a
            # zero-argument signature, not the strategy parameters
            # (it would treat them as fixtures).
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(zlib.crc32(test.__qualname__.encode()))
                for _ in range(n):
                    vals = [s.example(rng) for s in strategies]
                    test(*vals)
            wrapper.__name__ = test.__name__
            wrapper.__qualname__ = test.__qualname__
            wrapper.__doc__ = test.__doc__
            wrapper.__module__ = test.__module__
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(test):
            test._max_examples = max_examples
            return test
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
