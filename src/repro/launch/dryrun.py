import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent end-to-end —
.lower().compile() fails on sharding mismatch / unsupported collective /
compile-time OOM — and records memory_analysis / cost_analysis / parsed
roofline terms into experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --force         # re-run cached
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models import api
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel.sharding import axis_rules
from repro.launch import roofline, steps
from repro.launch.mesh import make_production_mesh

OUT_DIR = pathlib.Path("experiments/dryrun")


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "serialized_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out and ma is not None:
        out["repr"] = str(ma)
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


def lower_cell(cfg: ModelConfig, shape: api.ShapeSpec, mesh,
               seq_shard: bool = False, remat_policy: str | None = None,
               moment_dtype: str | None = None):
    """Returns (lowered, compiled, wall_times). Raises on any failure."""
    mode = shape.kind
    rules = steps.rules_for(mesh, shape, seq_shard=seq_shard)
    if moment_dtype is None:
        # trillion-scale cells use bf16 moments (see DESIGN.md memory notes)
        moment_dtype = "bfloat16" if cfg.param_count() > 1e11 else "float32"
    ocfg = adamw.AdamWConfig(moment_dtype=moment_dtype)
    with mesh, axis_rules(rules, mesh):
        if mode == "train":
            params, opt = steps.abstract_state(cfg, ocfg)
            batch = steps.abstract_batch(cfg, shape, "train")
            pspec = steps.param_pspecs(params, rules)
            ospec = steps.opt_pspecs(pspec)
            bspec = steps.batch_pspecs(batch, mesh, shape)
            fn = steps.make_train_step(cfg, ocfg)
            jitted = jax.jit(
                fn,
                in_shardings=(steps.named(mesh, pspec),
                              steps.named(mesh, ospec),
                              steps.named(mesh, bspec)),
                out_shardings=(steps.named(mesh, pspec),
                               steps.named(mesh, ospec), None),
                donate_argnums=(0, 1),
            )
            t0 = time.time()
            lowered = jitted.lower(params, opt, batch)
        elif mode == "prefill":
            params = steps.abstract_state(cfg)
            batch = steps.abstract_batch(cfg, shape, "prefill")
            pspec = steps.param_pspecs(params, rules)
            bspec = steps.batch_pspecs(batch, mesh, shape)
            fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(fn, in_shardings=(steps.named(mesh, pspec),
                                               steps.named(mesh, bspec)))
            t0 = time.time()
            lowered = jitted.lower(params, batch)
        else:  # decode
            params = steps.abstract_state(cfg)
            caches = steps.abstract_caches(cfg, shape)
            token = jax.ShapeDtypeStruct((shape.global_batch, 1), "int32")
            pos = jax.ShapeDtypeStruct((), "int32")
            pspec = steps.param_pspecs(params, rules)
            cspec = steps.cache_pspecs(caches, mesh, shape)
            fn = steps.make_serve_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(steps.named(mesh, pspec),
                              steps.named(mesh, cspec),
                              jax.sharding.NamedSharding(mesh, P()),
                              jax.sharding.NamedSharding(mesh, P())),
                out_shardings=(None, steps.named(mesh, cspec)),
                donate_argnums=(1,),
            )
            t0 = time.time()
            lowered = jitted.lower(params, caches, token, pos)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return lowered, compiled, {"lower_s": t_lower, "compile_s": t_compile}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             force: bool = False, **lower_kw) -> dict:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = OUT_DIR / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    cfg = ARCHS[arch]
    shape = api.SHAPES[shape_name]
    ok, reason = api.cell_supported(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mode": shape.kind, "status": "skipped", "reason": reason,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if not ok:
        out_path.write_text(json.dumps(rec, indent=1))
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    try:
        lowered, compiled, times = lower_cell(cfg, shape, mesh, **lower_kw)
        hlo = compiled.as_text()
        stats = roofline.analyze_hlo(hlo)
        terms = roofline.roofline_terms(stats, n_dev)
        mf = roofline.model_flops(cfg, shape, shape.kind)
        rec.update(
            status="ok",
            times=times,
            n_devices=n_dev,
            memory_analysis=_mem_analysis(compiled),
            cost_analysis=_cost_analysis(compiled),
            roofline=terms,
            model_flops=mf,
            useful_flops_ratio=(mf / terms["flops_global"]
                                if terms["flops_global"] else 0.0),
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # failure IS the signal: record and re-raise later
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(api.SHAPES))
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(api.SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind, force=args.force,
                               seq_shard=args.seq_shard)
                dt = time.time() - t0
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t>={r['step_time_lower_bound_s']:.3g}s"
                             f" useful={rec['useful_flops_ratio']:.2f}")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                    failures.append((arch, shape, mesh_kind))
                print(f"[{status:7s}] {arch:20s} {shape:12s} {mesh_kind:6s}"
                      f" ({dt:6.1f}s){extra}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("ALL CELLS OK")


if __name__ == "__main__":
    main()
