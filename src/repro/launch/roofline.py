"""Roofline-term extraction from compiled HLO (dry-run profiling).

Why not just ``compiled.cost_analysis()``: our deep stacks lower through
``lax.scan`` (compile-time sanity for 96-layer models), and XLA's
HloCostAnalysis visits a while-loop body ONCE — under-counting FLOPs and
collective bytes by the trip count. We therefore do call-graph-aware
accounting over ``compiled.as_text()``:

  * computations are parsed and linked (while body/cond, fusion calls, ...);
  * each computation gets a multiplier = product of enclosing loop trip
    counts (trip count recovered from the loop-condition constant);
  * FLOPs  = sum over dot ops: 2 * numel(out) * contracted_size * multiplier;
  * collective bytes = sum of operand bytes over all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (x multiplier);
  * memory bytes = operand+output bytes of top-level (fusion-boundary) ops —
    an HBM-traffic proxy that respects fusion.

All numbers come from the SPMD-partitioned per-device module; multiply by
device count for cluster totals (the roofline terms divide it back out).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Computation:
    name: str
    lines: list


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation headers: "%name (args...) -> type {" / "ENTRY %name ... {"
        if (stripped.endswith("{") and " -> " in stripped
                and not stripped.startswith("ROOT")):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = Computation(m.group(2), [])
                comps[cur.name] = cur
                if m.group(1):
                    comps["__entry__"] = cur
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            cur.lines.append(stripped)
    return comps


def _called(line: str) -> list[tuple[str, str]]:
    """(kind, computation) references on an op line."""
    out = []
    for kw in ("body", "condition", "to_apply", "calls", "branch_computations",
               "called_computations"):
        for m in re.finditer(kw + r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?",
                             line):
            for name in re.split(r",\s*", m.group(1)):
                out.append((kw, name.lstrip("%")))
    return out


def _trip_count(comp: Computation) -> int:
    """Loop condition: compare(iter, constant(N)) -> N (fallback 1)."""
    consts = []
    for ln in comp.lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def computation_multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    entry = comps.get("__entry__")
    mult = {c: 0.0 for c in comps if c != "__entry__"}
    if entry is None:
        for c in mult:
            mult[c] = 1.0
        return mult
    mult[entry.name] = 1.0
    # propagate in topological-ish order via repeated passes (call graph is a DAG)
    for _ in range(len(comps)):
        changed = False
        for name, comp in comps.items():
            if name == "__entry__" or mult.get(name, 0.0) == 0.0:
                continue
            m_here = mult[name]
            for ln in comp.lines:
                refs = _called(ln)
                if not refs:
                    continue
                is_while = " while(" in ln or ln.startswith("while")
                trip = 1
                if is_while:
                    cond_name = next((r[1] for r in refs if r[0] == "condition"),
                                     None)
                    if cond_name and cond_name in comps:
                        trip = _trip_count(comps[cond_name])
                for kind, ref in refs:
                    if ref not in comps:
                        continue
                    factor = trip if (is_while and kind in ("body", "condition")) \
                        else 1
                    want = m_here * factor
                    if want > mult.get(ref, 0.0):
                        mult[ref] = want
                        changed = True
        if not changed:
            break
    return mult


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
                     r"(\([^={]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                     r"([a-z][a-z0-9\-]*)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _operands(line: str) -> list[str]:
    """Operand names inside the op's argument parens."""
    m = re.search(r"\s[a-z][a-z0-9\-]*\((.*)$", line)
    if not m:
        return []
    args = m.group(1)
    # cut at "), " attribute boundary heuristically
    depth, end = 1, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(args[:end])


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0                 # per-device
    memory_bytes: float = 0.0          # per-device HBM-traffic proxy
    collective_bytes: float = 0.0      # per-device, sum of operand bytes
    collective_ops: dict = dataclasses.field(default_factory=dict)
    dot_flops_unscaled: float = 0.0


_SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy-start", "copy-done", "after-all", "partition-id", "while",
             "conditional", "call"}

_PARAM_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
                       r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+parameter\((\d+)\)")


def _fusion_slice_bytes(fused: Computation) -> tuple[dict, int | None]:
    """Slice-aware byte accounting for a fusion body.

    Returns (param_bytes, root_update_bytes):
      * param_bytes[i] — HBM bytes actually read for parameter i. When a
        parameter is consumed ONLY by dynamic-slice ops, the traffic is the
        slice size (the while-loop scan pattern: the full (T, ...) buffer
        stays resident; each iteration reads one window). Otherwise the
        full parameter size.
      * root_update_bytes — when the fusion ROOT is dynamic-update-slice,
        the written bytes are the update operand's size (in-place
        accumulator), not the whole buffer; None if the root is anything
        else.
    """
    params: dict[str, tuple[int, str]] = {}   # name -> (index, type)
    defs: dict[str, str] = {}
    ops = []
    root = None
    for ln in fused.lines:
        pm = _PARAM_RE.match(ln)
        if pm:
            params[pm.group(1)] = (int(pm.group(3)), pm.group(2))
            defs[pm.group(1)] = pm.group(2)
            continue
        dm = _DEF_RE.match(ln)
        if dm:
            defs[dm.group(1)] = dm.group(2)
            ops.append((dm.group(1), dm.group(2), dm.group(3), ln))
            if ln.strip().startswith("ROOT"):
                root = (dm.group(1), dm.group(2), dm.group(3), ln)
    # consumers of each param
    reads: dict[int, int] = {}
    consumed_by: dict[str, list[tuple[str, str]]] = {p: [] for p in params}
    for out_name, out_type, kind, ln in ops:
        for op in _operands(ln):
            if op in consumed_by:
                consumed_by[op].append((kind, out_type))
    for pname, (idx, ptype) in params.items():
        uses = consumed_by[pname]
        if uses and all(k == "dynamic-slice" for k, _ in uses):
            reads[idx] = sum(_type_bytes(t) for _, t in uses)
        else:
            reads[idx] = _type_bytes(ptype)
    root_update = None
    if root is not None and root[2] == "dynamic-update-slice":
        ops_in = _operands(root[3])
        if len(ops_in) >= 2 and ops_in[1] in defs:
            root_update = _type_bytes(defs[ops_in[1]])
    return reads, root_update


def _callee_kinds(comps) -> dict[str, set]:
    kinds: dict[str, set] = {}
    for comp in comps.values():
        for ln in comp.lines:
            for kind, ref in _called(ln):
                kinds.setdefault(ref, set()).add(kind)
    return kinds


def analyze_hlo(hlo: str) -> HloStats:
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    ckinds = _callee_kinds(comps)
    entry = comps.get("__entry__")
    stats = HloStats()
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0) or 1.0
        # fusion/reducer bodies are *inside* a kernel: not an HBM boundary.
        kinds = ckinds.get(name, set())
        is_entry = entry is not None and name == entry.name
        top_level = is_entry or bool(kinds & {"body", "condition",
                                              "branch_computations"})
        # local def map: name -> (type_str, op_kind)
        defs: dict[str, str] = {}
        parsed = []
        for ln in comp.lines:
            dm = _DEF_RE.match(ln)
            if dm:
                defs[dm.group(1)] = dm.group(2)
                parsed.append((dm.group(1), dm.group(2), dm.group(3), ln))
        for out_name, out_type, kind, ln in parsed:
            if kind == "dot":
                km = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                ops = _operands(ln)
                if km and ops and ops[0] in defs:
                    lhs_shapes = _SHAPE_RE.findall(defs[ops[0]])
                    if lhs_shapes:
                        lhs = [int(d) for d in lhs_shapes[0][1].split(",") if d]
                        k = 1
                        for idx in km.group(1).split(","):
                            if idx and int(idx) < len(lhs):
                                k *= lhs[int(idx)]
                        f = 2.0 * sum(_shape_numel(d) for _, d in
                                      _SHAPE_RE.findall(out_type)) * k
                        stats.flops += m * f
                        stats.dot_flops_unscaled += f
            base = kind.replace("-start", "")
            if base in COLLECTIVES:
                b = 0
                for op in _operands(ln):
                    if op in defs:
                        b += _type_bytes(defs[op])
                if b == 0:  # fall back to output size (all-reduce: equal)
                    b = _type_bytes(out_type)
                stats.collective_bytes += m * b
                stats.collective_ops[base] = stats.collective_ops.get(base, 0) + 1
            if top_level and kind not in _SKIP_MEM:
                reads, root_update = {}, None
                if kind == "fusion":
                    callee = next((r for k, r in _called(ln) if k == "calls"),
                                  None)
                    if callee and callee in comps:
                        reads, root_update = _fusion_slice_bytes(comps[callee])
                b = _type_bytes(out_type) if root_update is None else root_update
                for i, op in enumerate(_operands(ln)):
                    if i in reads:
                        b += reads[i]
                    elif op in defs:
                        b += _type_bytes(defs[op])
                stats.memory_bytes += m * b
    return stats


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(stats: HloStats, n_devices: int) -> dict:
    """Seconds per step for each roof, from per-device stats."""
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.memory_bytes / HBM_BW
    collective_s = stats.collective_bytes / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "flops_per_device": stats.flops,
        "flops_global": stats.flops * n_devices,
        "memory_bytes_per_device": stats.memory_bytes,
        "collective_bytes_per_device": stats.collective_bytes,
        "collective_ops": stats.collective_ops,
    }
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])
    terms["bottleneck"] = dom[0]
    terms["step_time_lower_bound_s"] = dom[1]
    return terms


def model_flops(cfg, shape, mode: str) -> float:
    """MODEL_FLOPS: 6*N*D for train (3x fwd+bwd), 2*N*D forward-only.

    N = active params, D = tokens processed.
    """
    n = cfg.active_param_count()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
