"""Production mesh construction (multi-pod dry-run contract).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for unit tests (requires forced host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    out = 1
    for a in dp_axes(mesh):
        out *= sizes[a]
    return out
