"""Jittable train / prefill / serve steps + sharding-spec derivation.

These are the functions the dry-run lowers and the drivers execute. All
sharding is expressed as PartitionSpec pytrees derived here; the model code
itself only carries logical-axis constraints.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import api
from ..models.config import ModelConfig
from ..optim import adamw
from ..parallel.sharding import AxisRules, make_rules, param_sharding_specs
from .mesh import dp_axes, dp_size, mesh_axis_sizes


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, ocfg: adamw.AdamWConfig):
    lfn = api.loss_fn(cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lfn, has_aux=True)(params, batch)
        lr_scale = adamw.cosine_lr(opt_state["step"], 2000, 100_000)
        new_params, new_opt, gnorm = adamw.update(
            grads, opt_state, params, ocfg, lr_scale)
        out = {"loss": loss, "grad_norm": gnorm}
        out.update(metrics)
        return new_params, new_opt, out

    return train_step


def make_prefill_step(cfg: ModelConfig):
    pfn = api.prefill_fn(cfg)

    def prefill_step(params, batch):
        logits, caches = pfn(params, batch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    dfn = api.decode_fn(cfg)

    def serve_step(params, caches, token, pos):
        logits, caches = dfn(params, caches, token, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches

    return serve_step


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def rules_for(mesh, shape: api.ShapeSpec | None = None,
              seq_shard: bool = False) -> AxisRules:
    multi = "pod" in mesh.axis_names
    rules = make_rules(multi, seq_shard=seq_shard)
    rules["kv_heads"] = None  # Hkv < TP width for most archs: replicate KV
    if shape is not None and shape.global_batch < dp_size(mesh):
        rules["batch"] = None           # e.g. long_500k: batch 1
        rules["tokens_flat"] = ("model",)
    return rules


def batch_pspecs(batch: Any, mesh, shape: api.ShapeSpec) -> Any:
    dp = dp_axes(mesh)
    bsh = None if shape.global_batch % dp_size(mesh) else dp

    def spec(leaf):
        s = [None] * leaf.ndim
        if leaf.ndim >= 1 and bsh:
            s[0] = bsh
        return P(*s)

    return jax.tree.map(spec, batch)


def cache_pspecs(caches: Any, mesh, shape: api.ShapeSpec) -> Any:
    """Shard caches: batch dim over DP when divisible; the largest remaining
    dim (typically the seq_len axis — flash-decoding style) over 'model'."""
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)
    msize = mesh_axis_sizes(mesh)["model"]

    def spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        stacked = any(n in ("layers", "dec") for n in names)
        s: list = [None] * leaf.ndim
        b_dim = 1 if (stacked and leaf.ndim >= 2) else 0
        if leaf.ndim > b_dim and leaf.shape[b_dim] % dpn == 0:
            s[b_dim] = dp
        rest = [(leaf.shape[i], i) for i in range(leaf.ndim)
                if i != b_dim and (not stacked or i > 0)]
        for size, i in sorted(rest, reverse=True):
            if size % msize == 0 and size >= msize:
                s[i] = "model"
                break
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, caches)


def param_pspecs(params: Any, rules: AxisRules) -> Any:
    return param_sharding_specs(
        params, rules, stacked_prefixes=("layers", "enc_layers", "dec_layers"))


def opt_pspecs(pspecs: Any) -> Any:
    return {"m": pspecs, "v": pspecs, "step": P()}


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Abstract (allocation-free) inputs for lowering
# ---------------------------------------------------------------------------

def abstract_state(cfg: ModelConfig, ocfg: adamw.AdamWConfig | None = None):
    params = jax.eval_shape(api.init_fn(cfg), jax.random.PRNGKey(0))
    if ocfg is None:
        return params
    opt = jax.eval_shape(functools.partial(adamw.init, cfg=ocfg), params)
    return params, opt


def abstract_batch(cfg: ModelConfig, shape: api.ShapeSpec, mode=None):
    return jax.eval_shape(
        functools.partial(api.input_specs, cfg, shape, mode))


def abstract_caches(cfg: ModelConfig, shape: api.ShapeSpec):
    return jax.eval_shape(
        functools.partial(api.init_caches, cfg, shape.global_batch,
                          shape.seq_len))
