"""End-to-end training driver: SOAR-scheduled gradient reduction + FT.

The driver wires every substrate layer together:

  data/SyntheticLM -> models/api loss -> shard_map(grad + SOAR reduce)
  -> optim/adamw -> checkpoint/CheckpointManager, with runtime/Orchestrator
  re-sowing the blue placement on injected failures or quarantined
  stragglers.

The data-parallel gradient reduction runs the *actual* SOAR reduction
program (collectives.reduce_local) when more than one device is visible;
metrics use plain psum. On a single CPU device the same code path runs with
a trivial mesh (the program degenerates to the identity, as the paper's
model does for a single server).

Usage (CPU example sizes; see examples/train_e2e.py):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
      --steps 50 --global-batch 8 --seq 128 --k 2 --ckpt-dir /tmp/ckpt
  # multi-device SOAR reduction (8 fake host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch granite-20b --reduced --steps 20
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import ckpt
from ..collectives import chip_level_tree
from ..collectives.tree_allreduce import reduce_local, _shard_map
from ..configs import ARCHS
from ..data.pipeline import DataConfig, SyntheticLM
from ..models import api
from ..models.config import ModelConfig
from ..optim import adamw
from ..optim.compression import (CompressionConfig, compress_tree,
                                 init_error_feedback, payload_bytes)
from ..runtime import Orchestrator, OrchestratorConfig


def dp_fleet(n_devices: int):
    """A chip-level reduction tree whose leaves are the dp devices."""
    # factor n_devices into pods x racks x chips (powers of two preferred)
    chips = 2 if n_devices % 2 == 0 and n_devices > 1 else 1
    rest = n_devices // chips
    pods = 2 if rest % 2 == 0 and rest > 1 else 1
    racks = max(1, rest // pods)
    assert pods * racks * chips == n_devices, (pods, racks, chips, n_devices)
    return chip_level_tree(n_pods=pods, racks_per_pod=racks,
                           chips_per_rack=chips)


def make_step(cfg: ModelConfig, ocfg: adamw.AdamWConfig, mesh, prog,
              grad_scale: float,
              ccfg: CompressionConfig = CompressionConfig()):
    """jit(shard_map(local grad [+ compress] + SOAR reduce) -> adamw).

    Compression (top-k/int8 with error feedback) happens on each worker's
    LOCAL gradient before the reduction — the paper's PS use case: sparse
    worker messages, in-network union-sum aggregation.
    """
    lfn = api.loss_fn(cfg)
    n_dev = prog.n_dev

    def local_grads(params, ef, batch):
        if n_dev > 1:  # per-device EF arrives with a leading shard dim of 1
            ef = jax.tree.map(lambda e: e[0], ef)
        (loss, metrics), grads = jax.value_and_grad(
            lfn, has_aux=True)(params, batch)
        grads, ef = compress_tree(grads, ef, ccfg)
        if n_dev > 1:
            ef = jax.tree.map(lambda e: e[None], ef)
            grads = jax.tree.map(
                lambda g: reduce_local(g, prog, "data") * (grad_scale / n_dev),
                grads)
            loss = jax.lax.pmean(loss, "data")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "data"), metrics)
        return loss, metrics, grads, ef

    if n_dev > 1:
        sharded = _shard_map(
            local_grads, mesh=mesh,
            in_specs=(P(), P("data"), P("data")),
            out_specs=(P(), P(), P(), P("data")),
        )
    else:
        sharded = local_grads

    @jax.jit
    def step(params, opt_state, ef, batch):
        loss, metrics, grads, ef = sharded(params, ef, batch)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params, ocfg)
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, ef, out

    return step


def mask_dead_batch(batch, alive, global_batch: int, n_dev: int):
    """Zero the batch shards of non-contributing devices.

    Dead/quarantined chips produce no gradient messages; their slice of
    the global batch is zeroed (a zero contribution to the sum) and the
    orchestrator's ``grad_scale`` re-normalizes the mean over survivors.
    """
    dead = np.nonzero(~np.asarray(alive, bool))[0]
    if not len(dead):
        return batch
    per = global_batch // n_dev
    mask = np.ones(global_batch, bool)
    for d in dead:
        mask[d * per:(d + 1) * per] = False
    m = jnp.asarray(mask)
    return {k: jnp.where(m[:, None] if v.ndim > 1 else m, v, 0)
            for k, v in batch.items()}


def parse_failures(spec: str | None) -> dict[int, list[int]]:
    """--fail "30:0,1;60:5" -> {30: [0, 1], 60: [5]}."""
    out: dict[int, list[int]] = {}
    if not spec:
        return out
    for part in spec.split(";"):
        step_s, devs = part.split(":")
        out[int(step_s)] = [int(d) for d in devs.split(",")]
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--preset-100m", action="store_true",
                    help="~100M-param config for the e2e example")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--k", type=int, default=2, help="SOAR blue budget")
    ap.add_argument("--strategy", default="soar")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail", default=None,
                    help='inject failures, e.g. "30:0;60:2,3" (step:devices)')
    ap.add_argument("--compress", default=None,
                    help='gradient compression: "topk:0.01" | "int8"')
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.preset_100m:
        cfg = cfg.reduced(n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                          d_ff=2048, vocab=32_768, head_dim=0)
    elif args.reduced:
        cfg = cfg.reduced()
    if cfg.param_count() > 1e9:
        raise SystemExit("full-size config on CPU driver; pass --reduced")
    print(f"arch={cfg.name} params={cfg.param_count():,}")

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    topo = dp_fleet(n_dev)
    orch = Orchestrator(topo, OrchestratorConfig(k=args.k,
                                                 strategy=args.strategy))
    print(f"devices={n_dev} fleet_switches={topo.tree.n} k={args.k} "
          f"phi={orch.program.utilization:.1f} "
          f"msgs={orch.program.total_network_messages}")

    ocfg = adamw.AdamWConfig()
    ccfg = CompressionConfig.parse(args.compress)
    params = api.init_fn(cfg)(jax.random.PRNGKey(args.seed))
    opt_state = adamw.init(params, ocfg)
    if n_dev > 1:
        ef = jax.tree.map(lambda p: jnp.zeros((n_dev,) + p.shape,
                                              jnp.float32), params)
        ef = jax.device_put(ef, NamedSharding(mesh, P("data")))
    else:
        ef = init_error_feedback(params)
    if ccfg.kind != "none":
        dense_b = payload_bytes(params, CompressionConfig())
        comp_b = payload_bytes(params, ccfg)
        print(f"compression={ccfg.kind} worker payload "
              f"{dense_b/1e6:.1f} MB -> {comp_b/1e6:.2f} MB "
              f"({dense_b/comp_b:.0f}x)")
    data = SyntheticLM(cfg, DataConfig(args.global_batch, args.seq,
                                       seed=args.seed))

    mgr = ckpt.CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and args.resume:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, start = ckpt.restore(
                args.ckpt_dir, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

    failures = parse_failures(args.fail)
    step_fn = make_step(cfg, ocfg, mesh, orch.program, orch.grad_scale,
                        ccfg)
    if n_dev > 1:
        batch_sharding = NamedSharding(mesh, P("data"))
    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        if step in failures:
            orch.on_failure(failures[step])
            print(f"[step {step}] failure {failures[step]} -> replanned "
                  f"phi={orch.program.utilization:.1f} "
                  f"alive={orch.n_alive}")
            step_fn = make_step(cfg, ocfg, mesh, orch.program,
                                orch.grad_scale, ccfg)
        batch = data.batch(step)
        if n_dev > 1:
            batch = jax.tree.map(
                lambda x: jax.device_put(x, batch_sharding), batch)
            batch = mask_dead_batch(batch, orch.alive, args.global_batch,
                                    n_dev)
        params, opt_state, ef, metrics = step_fn(params, opt_state, ef,
                                                 batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt / max(1, step - start + 1):.2f}s/step)")
        if mgr and step > start and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
