"""Dry-run profiler: attribute roofline bytes/flops to individual HLO ops.

The §Perf hillclimb loop reads this instead of a wall-clock trace: for a
given (arch, shape, mesh) cell it prints the top-N ops by memory-traffic
contribution, the collective inventory, and duplicate-op counts (a remat /
redundant-collective smell test).

Usage:
  PYTHONPATH=src python -m repro.launch.profile_hlo --arch hymba-1.5b \
      --shape train_4k --mesh single --top 25
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re

from repro.launch import roofline


def op_breakdown(hlo: str, top: int = 25):
    comps = roofline.parse_computations(hlo)
    mult = roofline.computation_multipliers(comps)
    ckinds = roofline._callee_kinds(comps)
    entry = comps.get("__entry__")
    mem_by_kind = collections.Counter()
    mem_rows = []      # (bytes, comp, opname, kind, type)
    coll_rows = []
    flop_rows = []
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0) or 1.0
        kinds = ckinds.get(name, set())
        is_entry = entry is not None and name == entry.name
        top_level = is_entry or bool(kinds & {"body", "condition",
                                              "branch_computations"})
        defs = {}
        parsed = []
        for ln in comp.lines:
            dm = roofline._DEF_RE.match(ln)
            if dm:
                defs[dm.group(1)] = dm.group(2)
                parsed.append((dm.group(1), dm.group(2), dm.group(3), ln))
        for out_name, out_type, kind, ln in parsed:
            if kind == "dot":
                km = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                ops = roofline._operands(ln)
                if km and ops and ops[0] in defs:
                    lhs_shapes = roofline._SHAPE_RE.findall(defs[ops[0]])
                    if lhs_shapes:
                        lhs = [int(d) for d in lhs_shapes[0][1].split(",") if d]
                        kk = 1
                        for idx in km.group(1).split(","):
                            if idx and int(idx) < len(lhs):
                                kk *= lhs[int(idx)]
                        f = 2.0 * sum(roofline._shape_numel(d) for _, d in
                                      roofline._SHAPE_RE.findall(out_type)) * kk
                        flop_rows.append((m * f, name, out_name, out_type))
            base = kind.replace("-start", "")
            if base in roofline.COLLECTIVES:
                b = 0
                for op in roofline._operands(ln):
                    if op in defs:
                        b += roofline._type_bytes(defs[op])
                if b == 0:
                    b = roofline._type_bytes(out_type)
                coll_rows.append((m * b, name, out_name, base, out_type[:60]))
            if top_level and kind not in roofline._SKIP_MEM:
                reads, root_update = {}, None
                if kind == "fusion":
                    callee = next((r for k, r in roofline._called(ln)
                                   if k == "calls"), None)
                    if callee and callee in comps:
                        reads, root_update = roofline._fusion_slice_bytes(
                            comps[callee])
                b = (roofline._type_bytes(out_type) if root_update is None
                     else root_update)
                for i, op in enumerate(roofline._operands(ln)):
                    if i in reads:
                        b += reads[i]
                    elif op in defs:
                        b += roofline._type_bytes(defs[op])
                mem_rows.append((m * b, name, out_name, kind, out_type[:60]))
                mem_by_kind[kind] += m * b
    return mem_rows, coll_rows, flop_rows, mem_by_kind


def report(hlo: str, top: int = 25) -> None:
    mem_rows, coll_rows, flop_rows, mem_by_kind = op_breakdown(hlo, top)
    tot_mem = sum(r[0] for r in mem_rows)
    tot_coll = sum(r[0] for r in coll_rows)
    tot_flop = sum(r[0] for r in flop_rows)
    print(f"TOTAL mem={tot_mem/1e9:.2f} GB  coll={tot_coll/1e9:.3f} GB  "
          f"flops={tot_flop/1e12:.3f} T (per device)")
    print(f"\n-- memory by op kind --")
    for kind, b in mem_by_kind.most_common(12):
        print(f"  {kind:<22} {b/1e9:>10.2f} GB  ({100*b/max(tot_mem,1):.1f}%)")
    print(f"\n-- top {top} memory ops --")
    for b, comp, name, kind, t in sorted(mem_rows, reverse=True)[:top]:
        print(f"  {b/1e9:>9.2f} GB  {kind:<18} {t:<40} [{comp[:40]}]")
    print(f"\n-- collectives --")
    agg = collections.Counter()
    for b, comp, name, base, t in coll_rows:
        agg[base] += b
    for base, b in agg.most_common():
        print(f"  {base:<20} {b/1e9:>10.3f} GB")
    for b, comp, name, base, t in sorted(coll_rows, reverse=True)[:top]:
        print(f"  {b/1e6:>9.1f} MB  {base:<18} {t:<40} [{comp[:40]}]")
    print(f"\n-- top {min(top, 15)} dot ops --")
    for f, comp, name, t in sorted(flop_rows, reverse=True)[:min(top, 15)]:
        print(f"  {f/1e12:>9.3f} TF  {t:<44} [{comp[:40]}]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.models import api

    cfg = ARCHS[args.arch]
    shape = api.SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered, compiled, times = lower_cell(cfg, shape, mesh,
                                          seq_shard=args.seq_shard)
    print(f"[{args.arch} x {args.shape} x {args.mesh}] "
          f"lower={times['lower_s']:.1f}s compile={times['compile_s']:.1f}s")
    report(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
