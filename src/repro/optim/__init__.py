from . import adamw
from .adamw import AdamWConfig, cosine_lr, global_norm

__all__ = ["adamw", "AdamWConfig", "cosine_lr", "global_norm"]
