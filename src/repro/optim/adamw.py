"""AdamW with sharded first/second-moment states (no optax dependency).

Moment dtype is configurable: bf16 moments halve optimizer HBM (the setting
used for the trillion-parameter dry-run cells; see EXPERIMENTS.md §Dry-run
memory notes), fp32 is the small-model default.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"
    grad_clip: float = 1.0


def init(params: Any, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, opt_state, params, cfg: AdamWConfig,
           lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm


def cosine_lr(step, warmup: int, total: int, base: float = 1.0,
              floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base * warm * cos
