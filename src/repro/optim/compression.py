"""Gradient compression with error feedback (distributed-optimization trick).

Matches the paper's PS use case (§5.3): workers send *sparsified* gradients
and in-network aggregation unions them — the byte-complexity model the
paper evaluates. Two codecs:

  * top-k magnitude sparsification (ratio of entries kept per leaf);
  * int8 per-leaf absmax quantization.

Both carry an error-feedback accumulator (Karimireddy et al.-style): the
un-sent residual is added to the next step's gradient, so every coordinate
is eventually transmitted and SGD converges at the uncompressed rate.

The compressed gradient stays a dense array with zeros (sum-compatible with
any reduction tree, including the SOAR collective); the *bandwidth* saving
is the sparse payload (indices+values / int8 bytes) reported by
``payload_bytes`` — the same size model the paper's PS evaluation uses.
``kernels/topk_compress`` is the Pallas TPU kernel for the top-k selection;
this module is the jnp path used by the driver.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | topk | int8
    ratio: float = 0.01           # topk: fraction of entries kept per leaf

    @staticmethod
    def parse(spec: str | None) -> "CompressionConfig":
        """"topk:0.01" / "int8" / None."""
        if not spec or spec == "none":
            return CompressionConfig()
        if spec.startswith("topk"):
            ratio = float(spec.split(":")[1]) if ":" in spec else 0.01
            return CompressionConfig("topk", ratio)
        if spec == "int8":
            return CompressionConfig("int8")
        raise ValueError(f"unknown compression spec {spec!r}")


def init_error_feedback(params: Any):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_leaf(g32, ratio: float):
    n = g32.size
    k = max(1, int(round(ratio * n)))
    flat = g32.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    sent = jnp.where(mask, flat, 0.0).reshape(g32.shape)
    return sent, g32 - sent


def _int8_leaf(g32):
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    sent = q.astype(jnp.float32) * scale
    return sent, g32 - sent


def compress_tree(grads: Any, ef: Any, cfg: CompressionConfig):
    """(grads, error_feedback) -> (sent_grads, new_error_feedback).

    sent_grads is dense (zeros where dropped) in the original dtype.
    """
    if cfg.kind == "none":
        return grads, ef

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        if cfg.kind == "topk":
            sent, resid = _topk_leaf(g32, cfg.ratio)
        else:
            sent, resid = _int8_leaf(g32)
        return sent.astype(g.dtype), resid

    out = jax.tree.map(one, grads, ef)
    sent = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return sent, new_ef


def payload_bytes(params: Any, cfg: CompressionConfig) -> int:
    """Per-worker message size under the codec (the PS byte model)."""
    n = sum(p.size for p in jax.tree.leaves(params))
    if cfg.kind == "none":
        return 4 * n
    if cfg.kind == "int8":
        return n + 4 * len(jax.tree.leaves(params))   # int8 + scale/leaf
    k = sum(max(1, int(round(cfg.ratio * p.size)))
            for p in jax.tree.leaves(params))
    return 8 * k                                       # index + value
