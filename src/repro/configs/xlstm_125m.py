"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks (arXiv:2405.04517).

Assignment: 12L d_model=768 4H d_ff=0 vocab=50304 (no separate FFN; the
mixers carry their own projections).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    block_pattern=("m", "s"),
    chunk_size=256,
    scan_layers=False,
)
