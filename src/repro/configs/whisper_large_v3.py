"""whisper-large-v3 [audio] — enc-dec, conv frontend stub (arXiv:2212.04356).

Assignment: 32L d_model=1280 20H d_ff=5120 vocab=51866. 32 encoder + 32
decoder layers; the mel/conv frontend is a STUB (input_specs() provides
precomputed frame embeddings).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    is_encoder_decoder=True,
    n_encoder_layers=32,
    norm_type="layernorm",
    mlp_type="gelu",
    frontend="audio_stub",
    tie_embeddings=True,
)
