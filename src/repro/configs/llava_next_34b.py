"""llava-next-34b [vlm] — anyres tiling (hf:llava-hf/llava-v1.6).

Assignment: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The anyres vision frontend is a STUB: input_specs() provides precomputed
patch embeddings (n_prefix_embeds tokens) per the assignment.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20_480,
    vocab=64_000,
    frontend="vision_stub",
    n_prefix_embeds=2880,  # anyres: base 576 + 4 tiles x 576
    rope_theta=5_000_000.0,
)
