"""qwen3-32b [dense] — qk-norm, GQA (hf:Qwen/Qwen3-8B family).

Assignment: 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
head_dim=128 (n_heads*head_dim != d_model, as in Qwen3).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
