"""granite-20b [dense] — code model, MQA (arXiv:2405.04324).

Assignment: 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
d_ff = 4*d with a non-gated GELU MLP (gpt_bigcode-style 4x ratio).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24_576,
    vocab=49_152,
    mlp_type="gelu",
)
