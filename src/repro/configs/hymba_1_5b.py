"""hymba-1.5b [hybrid] — parallel attention + mamba heads (arXiv:2411.13676).

Assignment: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16. Sliding-window attention except 3 global layers (first /
middle / last), making the arch sub-quadratic for long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    ssm_state=16,
    d_inner_mult=2.0,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    tie_embeddings=True,
    scan_layers=False,
)
