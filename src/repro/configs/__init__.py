"""Assigned-architecture registry: ``get(name)`` / ``ARCHS``."""
from . import (
    deepseek_v2_236b,
    granite_20b,
    hymba_1_5b,
    kimi_k2_1t_a32b,
    llava_next_34b,
    minicpm3_4b,
    nemotron_4_340b,
    qwen3_32b,
    whisper_large_v3,
    xlstm_125m,
)

_MODULES = [
    kimi_k2_1t_a32b, deepseek_v2_236b, granite_20b, nemotron_4_340b,
    qwen3_32b, minicpm3_4b, llava_next_34b, xlstm_125m, hymba_1_5b,
    whisper_large_v3,
]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]
