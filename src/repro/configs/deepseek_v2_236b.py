"""deepseek-v2-236b [moe] — MLA + 2 shared / 160 routed top-6 (arXiv:2405.04434).

Assignment: 60L d_model=5120 128H d_ff=1536 vocab=102400, MLA kv_lora=512.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,            # dense-prefix layer width (HF config)
    vocab=102_400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1536,
    moe_dense_prefix=1,
)
