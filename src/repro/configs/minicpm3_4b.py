"""minicpm3-4b [dense] — MLA (hf:openbmb/MiniCPM3-4B).

Assignment: 62L d_model=2560 40H d_ff=6400 vocab=73448.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73_448,
    attn_type="mla",
    kv_lora_rank=256,
    q_lora_rank=768,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    tie_embeddings=True,
)
