"""kimi-k2-1t-a32b [moe] — Kimi K2 trillion-param MoE (arXiv:2501.kimi2).

Assignment: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384e top-8. The assignment's d_ff=2048 is the per-expert width; the
single dense first layer uses the HF config's 18432.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=18432,            # dense-prefix layer width
    vocab=163_840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    d_ff_expert=2048,
    moe_dense_prefix=1,
    rope_theta=50_000.0,
)
