"""SOAR collective schedule: static program properties + multi-device
equivalence (subprocess: forced host device count must precede jax init)."""
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.collectives import build_program, chip_level_tree, fleet_tree, plan
from repro.core.reduce import all_blue, all_red, messages_up, phi
from repro.core.soar import soar


def test_fleet_tree_structure():
    topo = fleet_tree(n_pods=2, racks_per_pod=4, chips_per_rack=4)
    assert topo.n_devices == 32
    assert topo.load.sum() == 32
    assert topo.tree.height == 2  # spine -> pod -> rack


def test_program_message_count_matches_phi_simulator():
    topo = chip_level_tree(2, 2, 2)
    for k in (0, 1, 3):
        blue, prog = plan(topo, k)
        msgs = messages_up(topo.tree, topo.load, blue)
        assert prog.total_network_messages == msgs.sum()
        assert prog.utilization == pytest.approx(
            phi(topo.tree, topo.load, blue))


def test_soar_placement_on_fleet_beats_baselines():
    topo = fleet_tree(n_pods=2, racks_per_pod=8, chips_per_rack=8)
    res = soar(topo.tree, topo.load, 4)
    for s in ("top", "max", "level", "random"):
        _, prog = plan(topo, 4, strategy=s)
        assert res.cost <= prog.utilization + 1e-9


def test_heterogeneous_rates_prefer_below_dcn_aggregation():
    """With expensive DCN links, SOAR should aggregate at/below pods."""
    topo = fleet_tree(n_pods=2, racks_per_pod=4, chips_per_rack=8)
    res = soar(topo.tree, topo.load, 2)
    t = topo.tree
    picked = np.nonzero(res.blue)[0]
    assert len(picked) == 2
    # both picks are pod switches (depth 1): collapse 32 msgs before the DCN
    assert all(t.depth[v] == 1 for v in picked)


def test_all_blue_program_sends_one_message_per_edge():
    topo = chip_level_tree(2, 2, 2)
    prog = build_program(topo, all_blue(topo.tree))
    assert prog.total_network_messages == topo.tree.n  # one per up-edge


@pytest.mark.slow
def test_tree_allreduce_equals_psum_subprocess():
    script = pathlib.Path(__file__).parent / "helpers" / "collective_check.py"
    env = {"PYTHONPATH": "src"}
    import os
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run([sys.executable, str(script)], cwd=str(
        pathlib.Path(__file__).parent.parent), env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "COLLECTIVE_CHECK_OK" in out.stdout


# ---------------------------------------------------------------------------
# fail_devices state-corruption regressions
# ---------------------------------------------------------------------------

def test_fail_devices_duplicate_ids_release_load_once():
    """A duplicated id in `dead` must fail the device once, not drain its
    leaf's load twice."""
    from repro.collectives import fail_devices
    topo = fleet_tree(2, 2, 2)
    t2 = fail_devices(topo, [3, 3, 3])
    assert t2.load.sum() == topo.load.sum() - 1
    assert t2.device_leaf[3] == -1
    assert (t2.load >= 0).all()


def test_fail_devices_already_dead_raises_and_preserves_last_switch():
    """Failing an already-failed device used to index load[-1] and silently
    drain the *last* switch's load; it must raise instead."""
    from repro.collectives import fail_devices
    topo = fleet_tree(2, 2, 2)
    once = fail_devices(topo, [0])
    last_load = once.load[-1]
    with pytest.raises(ValueError):
        fail_devices(once, [0])
    assert once.load[-1] == last_load          # untouched by the rejected call
    with pytest.raises(ValueError):
        fail_devices(topo, [topo.n_devices])   # out-of-range id


def test_plan_batch_rejects_mismatched_avail_lengths():
    """plan_batch used to zip-truncate silently when len(avails) !=
    len(topos); now it is a hard error."""
    from repro.collectives.schedule import plan_batch
    topo = fleet_tree(2, 2, 2)
    with pytest.raises(ValueError):
        plan_batch([topo, topo], 2, [None])
    with pytest.raises(ValueError):
        plan_batch([topo], 2, [None, None], strategy="top")
