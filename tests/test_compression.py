"""Gradient compression + error feedback invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import (CompressionConfig, compress_tree,
                                     init_error_feedback, payload_bytes)


def tree_of(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"w{i}": jax.random.normal(k, s) for i, (k, s) in
            enumerate(zip(ks, shapes))}


def test_parse():
    assert CompressionConfig.parse(None).kind == "none"
    assert CompressionConfig.parse("topk:0.05").ratio == 0.05
    assert CompressionConfig.parse("int8").kind == "int8"
    with pytest.raises(ValueError):
        CompressionConfig.parse("zstd")


def test_topk_keeps_largest_and_ef_holds_rest():
    g = {"w": jnp.asarray([1.0, -5.0, 0.1, 3.0])}
    ef = init_error_feedback(g)
    cfg = CompressionConfig("topk", 0.5)
    sent, ef2 = compress_tree(g, ef, cfg)
    np.testing.assert_allclose(np.asarray(sent["w"]), [0, -5.0, 0, 3.0])
    np.testing.assert_allclose(np.asarray(ef2["w"]), [1.0, 0, 0.1, 0])
    # identity: sent + residual == gradient + old ef
    np.testing.assert_allclose(np.asarray(sent["w"] + ef2["w"]),
                               np.asarray(g["w"]))


@pytest.mark.parametrize("kind,ratio", [("topk", 0.25), ("int8", 0.0)])
def test_error_feedback_transmits_everything_eventually(kind, ratio):
    """Constant gradient g: cumulative sent -> t*g with bounded residual."""
    g = tree_of(jax.random.PRNGKey(0), [(64,), (8, 8)])
    cfg = CompressionConfig(kind, ratio)
    ef = init_error_feedback(g)
    total = jax.tree.map(jnp.zeros_like, g)
    T = 30
    for _ in range(T):
        sent, ef = compress_tree(g, ef, cfg)
        total = jax.tree.map(lambda a, b: a + b, total, sent)
    for k in g:
        resid = np.asarray(total[k] - T * g[k])
        bound = np.abs(np.asarray(g[k])).max() * (T if kind == "none" else 3)
        assert np.abs(resid).max() <= bound  # residual bounded, not growing
        # and the dominant mass went through
        assert np.linalg.norm(np.asarray(total[k])) > 0.5 * T * \
            np.linalg.norm(np.asarray(g[k])) * (0.2 if kind == "topk" else 0.9)


def test_int8_roundtrip_error_bound():
    g = tree_of(jax.random.PRNGKey(1), [(128,)])
    sent, ef = compress_tree(g, init_error_feedback(g),
                             CompressionConfig("int8"))
    scale = float(jnp.abs(g["w0"]).max()) / 127.0
    assert float(jnp.abs(ef["w0"]).max()) <= scale * 0.5 + 1e-7


def test_payload_bytes_ordering():
    g = tree_of(jax.random.PRNGKey(2), [(1000,)])
    none_b = payload_bytes(g, CompressionConfig.parse(None))
    int8_b = payload_bytes(g, CompressionConfig.parse("int8"))
    topk_b = payload_bytes(g, CompressionConfig.parse("topk:0.01"))
    assert topk_b < int8_b < none_b


def test_training_still_converges_with_compression():
    """Tiny quadratic: compressed-EF SGD reaches near the optimum."""
    w_star = jnp.asarray([1.0, -2.0, 3.0, 0.5])
    cfg = CompressionConfig("topk", 0.25)

    def loss(w):
        return jnp.sum((w - w_star) ** 2)

    w = jnp.zeros(4)
    ef = {"w": jnp.zeros(4)}
    for _ in range(200):
        g = jax.grad(loss)(w)
        sent, ef = compress_tree({"w": g}, ef, cfg)
        w = w - 0.1 * sent["w"]
    assert float(loss(w)) < 1e-3
