"""Device-resident congestion loop vs host reference — bit parity — plus
the unified planner API (EngineOptions / TenantPlan / CongestionPlan).

The device loop (one jitted ``lax.while_loop``) and the host driver run
the same jitted float32 round arithmetic, so with ``record_rounds=True``
they must agree round for round *bitwise*: same effective rho, same
masks, same C_max history, same best round. Not approximately — exactly
(see the parity notes in ``engine/congestion.py``).
"""
import warnings

import numpy as np
import pytest

from repro.collectives import (CongestionPlan, TenantPlan, fleet_tree,
                               plan, plan_batch, plan_congestion)
from repro.core import bt
from repro.core.tree import sample_load
from repro.engine import EngineOptions, solve_congestion
from repro.runtime import Orchestrator, OrchestratorConfig


def _fleet(n=64, T=8, scheme="constant"):
    t = bt(n, scheme)
    loads = [sample_load(t, "power-law", seed=100 + s) for s in range(T)]
    return t, loads


def _assert_bit_identical(dev, host):
    assert dev.history == host.history                  # f32 C_max, exact
    assert dev.rounds == host.rounds
    assert dev.best_round == host.best_round
    assert np.array_equal(dev.blue, host.blue)
    assert dev.baseline_max == host.baseline_max
    assert dev.baseline_mean == host.baseline_mean
    assert dev.max_congestion == host.max_congestion
    assert np.array_equal(dev.msgs, host.msgs)
    for r, ((dr, db), (hr, hb)) in enumerate(
            zip(dev.rounds_log, host.rounds_log, strict=True)):
        assert np.array_equal(dr, hr), f"rho_eff differs at round {r}"
        assert np.array_equal(db, hb), f"masks differ at round {r}"


@pytest.mark.parametrize("config", ["plain", "rho_weighted", "avail",
                                    "priced"])
def test_device_loop_bit_identical_to_host_reference(config):
    t, loads = _fleet()
    kw = {}
    if config == "rho_weighted":
        kw = dict(rho_weighted=True)
    elif config == "avail":
        av = np.ones(t.n, bool)
        av[5:9] = False
        kw = dict(avail=[av if i % 2 else None for i in range(len(loads))])
    elif config == "priced":
        kw = dict(capacity=np.full(t.n, 3.0), cap_beta=1.5, cap_frac=0.5)
    dev = solve_congestion(t, loads, 4, record_rounds=True,
                           device_loop=True, **kw)
    host = solve_congestion(t, loads, 4, record_rounds=True,
                            device_loop=False, **kw)
    _assert_bit_identical(dev, host)


def test_device_loop_bit_identical_on_nondyadic_rates():
    # linear rates (1/(1+level)) are NOT exactly float32-representable, so
    # this checks the two paths share rounding, not that rounding is absent
    t, loads = _fleet(scheme="linear")
    dev = solve_congestion(t, loads, 4, record_rounds=True,
                           rho_weighted=True, device_loop=True)
    host = solve_congestion(t, loads, 4, record_rounds=True,
                            rho_weighted=True, device_loop=False)
    _assert_bit_identical(dev, host)


def test_device_loop_transfer_accounting():
    """The point of the resident loop: O(1) transfer per *call*, not per
    round — strictly less than the host driver's per-round pulls."""
    t, loads = _fleet(n=128, T=16)
    dev = solve_congestion(t, loads, 8, device_loop=True)
    host = solve_congestion(t, loads, 8, device_loop=False)
    assert dev.history == host.history                 # same trajectory
    assert dev.rounds == host.rounds >= 2
    assert 0 < dev.bytes_to_host < host.bytes_to_host
    # the device bill does not grow with the round count: masks + scalars
    T, S = len(loads), dev.blue.shape[1]
    assert dev.bytes_to_host < 4 * T * S + 4 * len(dev.history) * T + 4096


def test_capacity_pricing_steers_off_crowded_switches():
    """With per-switch capacity below the tenant count, pricing must cut
    the peak number of tenants stacked on one switch vs the unpriced run
    (that is the signal the orchestrator feeds it for)."""
    t, loads = _fleet(n=64, T=12)
    base = solve_congestion(t, loads, 4)
    priced = solve_congestion(t, loads, 4, capacity=np.full(t.n, 2.0),
                              cap_beta=4.0, cap_frac=0.5)
    peak = lambda r: int(r.blue.sum(axis=0).max())
    assert peak(priced) <= peak(base)
    # pricing shapes the search, never the reported objective: the result
    # is still monotone-best against its own utilization-only baseline
    assert priced.max_congestion <= priced.baseline_max


def test_driver_rejects_options_kwargs_mix_and_unknown():
    t, loads = _fleet(n=16, T=2)
    with pytest.raises(TypeError, match="both options="):
        solve_congestion(t, loads, 2, options=EngineOptions(), cap=False)
    with pytest.raises(TypeError, match="did you mean 'use_pallas'"):
        solve_congestion(t, loads, 2, use_palas=True)
    # the PR-4 kwargs shim is gone: a known field name raises with the
    # options=EngineOptions(...) migration instead of deprecation-warning
    with pytest.raises(TypeError, match="EngineOptions"):
        solve_congestion(t, loads, 2, cap=True, max_rounds=2)


def test_plan_batch_options_boundary():
    topo = fleet_tree(2, 2, 4)
    with pytest.raises(TypeError, match="did you mean 'dtype'"):
        plan_batch([topo], 2, dtyp=np.float32)
    with pytest.raises(TypeError, match="both options="):
        plan_batch([topo], 2, options=EngineOptions(), cap=False)
    with pytest.raises(TypeError, match="EngineOptions"):
        plan_batch([topo], 2, cap=True)                # shim removed
    with warnings.catch_warnings():
        warnings.simplefilter("error")                 # new spelling: clean
        new = plan_batch([topo], 2, options=EngineOptions(cap=True))
    # the options spelling is the default behavior, not a variant path
    assert np.array_equal(plan_batch([topo], 2)[0].blue, new[0].blue)
    # engine options make no sense for the serial baselines
    with pytest.raises(ValueError, match="only apply to"):
        plan_batch([topo], 2, strategy="top", options=EngineOptions())


def test_plan_returns_tenant_plan_and_delegates_to_engine():
    topo = fleet_tree(2, 4, 4)
    tp = plan(topo, 3, options=EngineOptions())
    assert isinstance(tp, TenantPlan)
    blue, prog = tp                                    # legacy unpacking
    assert blue is tp.blue and prog is tp.program
    assert tp.cost == prog.utilization
    # the single-topology path IS a batch of one now (identical masks —
    # historically plan() ran the serial solver and ignored options)
    batched = plan_batch([topo], 3)[0]
    assert np.array_equal(tp.blue, batched.blue)
    assert tp.cost == batched.cost
    # baselines still reject engine options
    with pytest.raises(ValueError):
        plan(topo, 3, strategy="top", options=EngineOptions())


def test_plan_congestion_returns_congestion_plan():
    topo = fleet_tree(2, 4, 4)
    cp = plan_congestion(topo, 3, count=4, max_rounds=4)
    assert isinstance(cp, CongestionPlan)
    planned, res = cp                                  # legacy unpacking
    assert planned is cp.plans and res is cp.result
    assert len(cp.plans) == 4
    assert all(isinstance(p, TenantPlan) for p in cp.plans)
    assert cp.max_congestion == res.max_congestion
    assert cp.improvement == res.improvement
    for p in cp.plans:
        assert p.cost == p.program.utilization


def test_orchestrator_capacity_priced_admission():
    topo = fleet_tree(2, 4, 4)
    orch = Orchestrator(topo, OrchestratorConfig(k=4, capacity=2))
    progs = orch.begin_workloads(3, congestion_aware=True,
                                 capacity_priced=True)
    assert len(progs) == 3
    assert (orch._residual >= 0).all()
    assert orch.last_congestion is not None
    # the flag is congestion-aware only, and owns the capacity signal
    orch2 = Orchestrator(topo, OrchestratorConfig(k=4, capacity=2))
    with pytest.raises(ValueError, match="congestion_aware"):
        orch2.begin_workloads(2, capacity_priced=True)
    with pytest.raises(ValueError, match="residual-capacity snapshot"):
        orch2.begin_workloads(2, congestion_aware=True, capacity_priced=True,
                              capacity=np.ones(topo.tree.n))
