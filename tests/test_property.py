"""Hypothesis property tests on phi-BIC invariants."""
import numpy as np
from repro.testing import given, settings, st

from repro.core.brute import brute_force
from repro.core.reduce import all_blue, all_red, phi, phi_barrier
from repro.core.soar import soar
from repro.core.soar_fast import soar_fast
from repro.core.tree import DEST, Tree


@st.composite
def tree_instances(draw, max_n=8):
    n = draw(st.integers(1, max_n))
    parent = [DEST] + [draw(st.integers(0, v - 1)) for v in range(1, n)]
    rho = [draw(st.floats(0.1, 4.0, allow_nan=False)) for _ in range(n)]
    load = [draw(st.integers(0, 6)) for _ in range(n)]
    avail = [draw(st.booleans()) for _ in range(n)]
    k = draw(st.integers(0, 3))
    return (
        Tree(np.array(parent), np.array(rho)),
        np.array(load, dtype=np.int64),
        np.array(avail, dtype=bool),
        k,
    )


@settings(max_examples=60, deadline=None)
@given(tree_instances())
def test_soar_is_optimal(inst):
    t, load, avail, k = inst
    _, want = brute_force(t, load, k, avail=avail)
    res = soar(t, load, k, avail=avail)
    assert abs(res.cost - want) < 1e-9 * max(1.0, abs(want))
    assert abs(phi(t, load, res.blue) - want) < 1e-9 * max(1.0, abs(want))
    assert res.blue.sum() <= k
    assert not np.any(res.blue & ~avail)


@settings(max_examples=60, deadline=None)
@given(tree_instances(max_n=16))
def test_fast_matches_reference(inst):
    t, load, avail, k = inst
    a = soar(t, load, k, avail=avail).cost
    b = soar_fast(t, load, k, avail=avail).cost
    assert abs(a - b) < 1e-9 * max(1.0, abs(a))


@settings(max_examples=40, deadline=None)
@given(tree_instances(max_n=16))
def test_cost_monotone_in_budget(inst):
    t, load, avail, k = inst
    costs = [soar(t, load, kk, avail=avail).cost for kk in range(k + 2)]
    assert all(a >= b - 1e-9 for a, b in zip(costs, costs[1:]))


@settings(max_examples=40, deadline=None)
@given(tree_instances(max_n=16), st.integers(0, 2**31 - 1))
def test_barrier_formulation_matches_simulation(inst, seed):
    t, load, avail, k = inst
    rng = np.random.default_rng(seed)
    blue = rng.random(t.n) < 0.4
    a, b = phi(t, load, blue), phi_barrier(t, load, blue)
    assert abs(a - b) < 1e-9 * max(1.0, abs(a))


@settings(max_examples=40, deadline=None)
@given(tree_instances(max_n=16))
def test_bounds_all_red_all_blue(inst):
    t, load, avail, k = inst
    c = soar(t, load, k).cost  # unrestricted availability
    assert c <= phi(t, load, all_red(t)) + 1e-9
    assert c >= phi(t, load, all_blue(t)) - 1e-9
    full = soar(t, load, t.n).cost
    assert full <= phi(t, load, all_blue(t)) + 1e-9


@settings(max_examples=30, deadline=None)
@given(tree_instances(max_n=12))
def test_more_availability_never_hurts(inst):
    t, load, avail, k = inst
    restricted = soar(t, load, k, avail=avail).cost
    free = soar(t, load, k).cost
    assert free <= restricted + 1e-9
