"""Expert-parallel MoE (shard_map all-to-all) equals the dense reference.

Multi-device equivalence runs in a subprocess (forced host device count must
precede jax init); local tests cover the binning helper directly.
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest


def test_sort_into_bins_capacity_and_order():
    import jax.numpy as jnp
    from repro.models.moe import _sort_into_bins
    bins = jnp.asarray([1, 0, 1, 1, 2, 0], jnp.int32)
    order, dest, keep = _sort_into_bins(bins, n_bins=3, capacity=2)
    # bin 1 has three items; the third (by stable order) is dropped
    assert int(keep.sum()) == 5
    kept_slots = np.asarray(dest)[np.asarray(keep)]
    assert len(set(kept_slots.tolist())) == 5          # no slot collisions
    assert (kept_slots < 6).all()


def test_invalid_bins_dropped():
    import jax.numpy as jnp
    from repro.models.moe import _sort_into_bins
    bins = jnp.asarray([3, 3, 1], jnp.int32)           # 3 == n_bins: invalid
    order, dest, keep = _sort_into_bins(bins, n_bins=3, capacity=4)
    assert int(keep.sum()) == 1


@pytest.mark.slow
def test_moe_ep_equals_dense_subprocess():
    script = pathlib.Path(__file__).parent / "helpers" / "moe_ep_check.py"
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run(
        [sys.executable, str(script)],
        cwd=str(pathlib.Path(__file__).parent.parent), env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MOE_EP_CHECK_OK" in out.stdout
