"""Fleet-native topology: the multi-tree placement stack.

Covers the N=1 degeneracy contract (a single-tree Fleet must round-trip
*bit-identically* through ``plan_fleet`` vs ``plan_congestion`` — same
masks, same costs, same per-round history; the engine path is shared,
not parallel), cross-tree congestion coupling through shared core links
(the trade two independent solves cannot make), device/host parity of
the fleet penalty loop, the global link-id space layout, call-boundary
validation, and the orchestrator's fleet admission + link-degrade
preplanning.
"""
import numpy as np
import pytest

from repro.collectives import (Fleet, FleetPlan, TenantPlan, build_fleet,
                               fleet_tree, plan_congestion, plan_fleet)
from repro.core.congestion import measure_fleet_multi
from repro.core.tree import sample_load
from repro.engine import solve_congestion, solve_fleet
from repro.runtime import Orchestrator, OrchestratorConfig
from repro.testing import given, settings, st


def _assert_fleet_matches_single(fl, single):
    """FleetPlan(N=1) vs CongestionPlan: every observable, bitwise."""
    assert isinstance(fl, FleetPlan)
    fr, sr = fl.result, single.result
    assert fr.history == sr.history            # f32 C_max trace, exact
    assert fr.rounds == sr.rounds
    assert fr.best_round == sr.best_round
    assert fr.max_congestion == sr.max_congestion
    assert fr.baseline_max == sr.baseline_max
    assert fr.baseline_mean == sr.baseline_mean
    assert np.array_equal(fr.msgs, sr.msgs)
    assert np.array_equal(fr.congestion, sr.congestion)
    for p, q in zip(fl.plans, single.plans, strict=True):
        assert isinstance(p, TenantPlan)
        assert np.array_equal(p.blue, q.blue)
        assert p.cost == q.cost
    if fr.rounds_log is not None:
        for r, ((fe, fb), (se, sb)) in enumerate(
                zip(fr.rounds_log, sr.rounds_log, strict=True)):
            assert np.array_equal(fe, se), f"rho_eff differs at round {r}"
            assert np.array_equal(fb, sb), f"masks differ at round {r}"


# ---------------------------------------------------------------------------
# N=1 degeneracy: plan_fleet IS plan_congestion, bit for bit


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000), st.booleans(), st.booleans())
def test_n1_fleet_round_trips_bit_identically(seed, rho_weighted, dev):
    """Property: a single-tree Fleet through plan_fleet equals
    plan_congestion on the topology — masks, costs, round history."""
    rng = np.random.default_rng(seed)
    topo = fleet_tree(int(rng.integers(2, 4)), 2, int(rng.integers(2, 4)))
    T = int(rng.integers(2, 5))
    k = int(rng.integers(1, 4))
    kw = dict(max_rounds=3, rho_weighted=rho_weighted, device_loop=dev,
              record_rounds=True)
    single = plan_congestion(topo, k, count=T, **kw)
    fl = plan_fleet(Fleet.single(topo), k, counts=[T], **kw)
    _assert_fleet_matches_single(fl, single)


def test_n1_fleet_parity_with_avail_and_capacity():
    topo = fleet_tree(2, 2, 4)
    n = topo.tree.n
    av = np.ones(n, bool)
    av[3:6] = False
    cap = np.full(n, 2.0)
    kw = dict(max_rounds=4, record_rounds=True, cap_beta=2.0, cap_frac=0.5)
    single = plan_congestion(topo, 3, count=4, avails=[av] * 4,
                             capacity=cap, **kw)
    fl = plan_fleet(Fleet.single(topo), 3, counts=[4], avails=[av] * 4,
                    capacity=[cap], **kw)
    _assert_fleet_matches_single(fl, single)


# ---------------------------------------------------------------------------
# cross-tree coupling: the trade independent solves cannot make


def test_hot_shared_core_trades_placements_independent_solves_cannot():
    """Two trees contending on an expensive shared spine: the coupled
    solve must aggregate root-side to shed core traffic — a placement no
    per-tree solve_congestion (blind to the core) produces — and must
    strictly cut the shared-core max congestion."""
    fleet = build_fleet(2, 2, 2, 2, spine_rho=64.0)
    trees = [tp.tree for tp in fleet.topos]
    T_per, k = 4, 2
    tree_of = [0] * T_per + [1] * T_per
    loads = [fleet.topos[g].load for g in tree_of]

    coupled = plan_fleet(fleet, k, counts=[T_per, T_per],
                         rho_weighted=True, max_rounds=6)
    indep_blues = []
    for tp in fleet.topos:
        r = solve_congestion(tp.tree, [tp.load] * T_per, k,
                             rho_weighted=True, max_rounds=6)
        indep_blues.extend(np.asarray(r.blue[t]) for t in range(T_per))

    kw = dict(core_rho=fleet.core_rho, core_path=fleet.core_path,
              rho_weighted=True)
    m_cpl = measure_fleet_multi(trees, tree_of, loads,
                                [p.blue for p in coupled.plans], **kw)
    m_ind = measure_fleet_multi(trees, tree_of, loads, indep_blues, **kw)
    # strictly less traffic on the shared core...
    assert m_cpl.core_congestion.max() < m_ind.core_congestion.max()
    # ...because the placements genuinely differ (the coupled DP sees the
    # core transit cost on every root-crossing message; the independent
    # solves cannot)
    assert any(not np.array_equal(p.blue, b)
               for p, b in zip(coupled.plans, indep_blues, strict=True))


def test_fleet_device_host_bit_parity_with_core():
    """The riskiest new path: N=2 trees + shared core through the jitted
    device while-loop vs the host driver — bitwise, round for round."""
    fleet = build_fleet(2, 2, 2, 2, spine_rho=8.0)
    trees = [tp.tree for tp in fleet.topos]
    tree_of = [0, 0, 0, 1, 1]
    loads = [sample_load(trees[g], "power-law", seed=10 + t)
             for t, g in enumerate(tree_of)]
    kw = dict(core_rho=fleet.core_rho, core_path=fleet.core_path,
              max_rounds=5, record_rounds=True, rho_weighted=True)
    dev = solve_fleet(trees, loads, tree_of, 2, device_loop=True, **kw)
    host = solve_fleet(trees, loads, tree_of, 2, device_loop=False, **kw)
    assert dev.history == host.history
    assert dev.rounds == host.rounds
    assert dev.best_round == host.best_round
    assert np.array_equal(dev.blue, host.blue)
    assert dev.baseline_max == host.baseline_max
    assert dev.baseline_mean == host.baseline_mean
    assert dev.max_congestion == host.max_congestion
    assert np.array_equal(dev.msgs, host.msgs)
    assert np.array_equal(dev.congestion, host.congestion)
    assert np.array_equal(dev.core_congestion, host.core_congestion)
    assert np.array_equal(dev.tree_of, host.tree_of)
    for r, ((de, db), (he, hb)) in enumerate(
            zip(dev.rounds_log, host.rounds_log, strict=True)):
        assert np.array_equal(de, he), f"rho_eff differs at round {r}"
        assert np.array_equal(db, hb), f"masks differ at round {r}"


def test_global_link_id_space_layout():
    """Per-link arrays use the fleet's global link-id space: tree
    segments at link_offsets, shared-core links last."""
    fleet = build_fleet(2, 2, 2, 2)
    n0, n1 = (tp.tree.n for tp in fleet.topos)
    assert fleet.link_offsets == (0, n0)
    assert fleet.core_offset == n0 + n1
    assert fleet.n_links == n0 + n1 + fleet.n_core
    fl = plan_fleet(fleet, 2, counts=[2, 2], max_rounds=2)
    assert fl.result.congestion.shape == (fleet.n_links,)
    assert fl.result.core_congestion.shape == (fleet.n_core,)
    assert np.array_equal(fl.result.congestion[fleet.core_offset:],
                          fl.result.core_congestion)
    assert np.array_equal(np.asarray(fl.tree_of), [0, 0, 1, 1])
    # every tenant's blues live inside its own tree's node range
    for t, p in enumerate(fl.plans):
        assert p.blue.shape == (fleet.topos[fl.tree_of[t]].tree.n,)


# ---------------------------------------------------------------------------
# call-boundary validation


def test_plan_fleet_validation():
    topo = fleet_tree(2, 2, 2)
    single = Fleet.single(topo)
    pair = build_fleet(2, 2, 2, 2)
    with pytest.raises(TypeError, match="Fleet.single"):
        plan_fleet(topo, 2, counts=[1])
    with pytest.raises(ValueError, match="exactly one of loads / counts"):
        plan_fleet(single, 2)
    with pytest.raises(ValueError, match="exactly one of loads / counts"):
        plan_fleet(single, 2, loads=[topo.load], tree_of=[0], counts=[1])
    with pytest.raises(ValueError, match="need tree_of"):
        plan_fleet(single, 2, loads=[topo.load])
    with pytest.raises(ValueError, match="derived from counts"):
        plan_fleet(single, 2, counts=[1], tree_of=[0])
    with pytest.raises(ValueError, match=">=1 tenants"):
        plan_fleet(pair, 2, counts=[2])            # one count, two trees
    with pytest.raises(ValueError, match="tree indices"):
        plan_fleet(single, 2, loads=[topo.load] * 2, tree_of=[0])
    with pytest.raises(ValueError, match=r"in \[0, 1\)"):
        plan_fleet(single, 2, loads=[topo.load], tree_of=[1])
    with pytest.raises(ValueError, match="pairs them positionally"):
        plan_fleet(single, 2, counts=[2], avails=[None])
    with pytest.raises(ValueError, match="one per tree"):
        plan_fleet(pair, 2, counts=[1, 1],
                   capacity=[np.ones(topo.tree.n)])
    with pytest.raises(ValueError, match="capacity shape"):
        plan_fleet(single, 2, counts=[1], capacity=[np.ones(3)])


def test_plan_congestion_boundary_validation():
    topo = fleet_tree(2, 2, 2)
    with pytest.raises(ValueError, match="pairs them positionally"):
        plan_congestion(topo, 2, count=3, avails=[None, None])
    with pytest.raises(ValueError, match="capacity shape"):
        plan_congestion(topo, 2, count=2, capacity=np.ones(3))
    with pytest.raises(ValueError, match="finite and"):
        plan_congestion(topo, 2, count=2,
                        capacity=np.full(topo.tree.n, np.nan))


def test_fleet_dataclass_validation():
    topo = fleet_tree(2, 2, 2)
    with pytest.raises(ValueError, match="empty fleet"):
        Fleet(topos=(), core_rho=np.zeros(0), core_path=())
    with pytest.raises(ValueError, match="core paths"):
        Fleet(topos=(topo,), core_rho=np.ones(1), core_path=())
    with pytest.raises(ValueError, match="out of range"):
        Fleet(topos=(topo,), core_rho=np.ones(1), core_path=((1,),))
    with pytest.raises(ValueError, match="repeats a link"):
        Fleet(topos=(topo,), core_rho=np.ones(1), core_path=((0, 0),))
    with pytest.raises(ValueError, match="positive"):
        Fleet(topos=(topo,), core_rho=np.asarray([-1.0]), core_path=((0,),))
    with pytest.raises(ValueError, match="at least one tree"):
        build_fleet(0)
    # uplink_rho gives each tree a dedicated attachment link + the spine
    fl = build_fleet(2, 2, 2, 2, spine_rho=16.0, uplink_rho=4.0)
    assert fl.n_core == 3 and fl.core_path == ((0, 2), (1, 2))


# ---------------------------------------------------------------------------
# orchestrator: fleet admission with per-tree capacity ledgers


def test_orchestrator_fleet_admission_claims_per_tree():
    fleet = build_fleet(2, 2, 2, 2)
    orch = Orchestrator(fleet, OrchestratorConfig(k=2, capacity=3))
    assert orch._residuals[0] is orch._residual    # tree 0 IS the ledger
    before = [r.copy() for r in orch._residuals]
    progs = orch.begin_workloads(congestion_aware=True, fleet=[2, 1])
    assert len(progs) == 3
    res = orch.last_congestion
    assert res is not None
    assert np.array_equal(np.asarray(res.tree_of), [0, 0, 1])
    # each tenant claimed against its own tree's ledger, nothing else
    for g in range(2):
        rows = [t for t in range(3) if res.tree_of[t] == g]
        n_g = fleet.topos[g].tree.n
        claimed = sum(int(res.blue[t, :n_g].sum()) for t in rows)
        assert int((before[g] - orch._residuals[g]).sum()) == claimed
        assert (orch._residuals[g] >= 0).all()


def test_orchestrator_fleet_admission_validation_and_n1():
    fleet = build_fleet(2, 2, 2, 2)
    orch = Orchestrator(fleet, OrchestratorConfig(k=2, capacity=3))
    with pytest.raises(ValueError, match="congestion_aware=True"):
        orch.begin_workloads(fleet=[1, 1])
    with pytest.raises(ValueError, match="exactly one of count / fleet"):
        orch.begin_workloads(congestion_aware=True)
    with pytest.raises(ValueError, match="exactly one of count / fleet"):
        orch.begin_workloads(2, congestion_aware=True, fleet=[1, 1])
    with pytest.raises(ValueError, match=">=1 workloads"):
        orch.begin_workloads(congestion_aware=True, fleet=[2])
    # a plain-topology orchestrator accepts fleet=[c]: the degenerate N=1
    topo = fleet_tree(2, 2, 2)
    o1 = Orchestrator(topo, OrchestratorConfig(k=2, capacity=3))
    progs = o1.begin_workloads(congestion_aware=True, fleet=[2],
                               capacity_priced=True)
    assert len(progs) == 2
    assert (o1._residual >= 0).all()


# ---------------------------------------------------------------------------
# preplan_link_degrades: cache-served recovery, bit-identical + staleness


def test_preplan_link_degrades_cache_hit_bit_identical():
    """A link-degrade served from the preplan cache must install the
    placement a fresh engine solve of that state would produce."""
    topo = fleet_tree(2, 2, 4)
    orch = Orchestrator(topo, OrchestratorConfig(k=3, capacity=4))
    planned = orch.preplan_link_degrades(factor=0.5)
    assert len(planned) == topo.tree.n         # every pristine up-link
    replans0, rec0 = orch.replans, orch.cache_recoveries
    orch.on_link_degrade({5: 0.5})
    assert orch.replans == replans0            # no engine solve
    assert orch.cache_recoveries == rec0 + 1
    cached_blue = orch.blue.copy()
    cached_util = orch.program.utilization
    # fresh orchestrator, same degrade, empty cache -> a real solve
    o2 = Orchestrator(topo, OrchestratorConfig(k=3, capacity=4))
    o2.on_link_degrade({5: 0.5})
    assert np.array_equal(cached_blue, o2.blue)
    assert cached_util == o2.program.utilization


def test_preplan_link_degrades_staleness_evicts():
    """Entries solved under a shifted capacity landscape must be evicted
    and solved around, exactly like preplan_switch_failures."""
    topo = fleet_tree(2, 2, 2)
    orch = Orchestrator(topo, OrchestratorConfig(k=2, capacity=1))
    orch.preplan_link_degrades(rate_sets=[{4: 0.5}])
    orch.begin_workload()                      # capacity landscape shifts
    rec0 = orch.cache_recoveries
    orch.on_link_degrade({4: 0.5})
    stats = orch.preplan_cache_stats()
    assert stats["stale"] == 1
    assert orch.cache_recoveries == rec0       # solved, not served
    assert (orch._residual >= 0).all()


def test_preplan_link_degrades_validation():
    topo = fleet_tree(2, 2, 2)
    orch = Orchestrator(topo, OrchestratorConfig(k=2))
    with pytest.raises(ValueError, match="out of range"):
        orch.preplan_link_degrades(rate_sets=[{topo.tree.n: 0.5}])
    with pytest.raises(ValueError, match="positive finite"):
        orch.preplan_link_degrades(rate_sets=[{0: 0.0}])
    with pytest.raises(ValueError, match="positive finite"):
        orch.preplan_link_degrades(factor=-1.0)
    # already-degraded links drop out of the default scenario set
    orch.on_link_degrade({3: 0.5})
    assert len(orch.preplan_link_degrades()) == topo.tree.n - 1
