"""Batched placement engine vs the serial reference DP.

Parity instances use dyadic rho (multiples of 1/8) so the engine's float32
tables are bit-exact against the float64 `soar` reference — equality
asserts are exact, not approximate (see engine/batched.py numerics note).
"""
import numpy as np
import pytest

from repro.core import bt, sample_load
from repro.core.forest import build_forest
from repro.core.reduce import phi
from repro.core.soar import soar
from repro.core.soar_fast import soar_fast
from repro.core.tree import DEST, Tree
from repro.engine import EngineOptions, solve_batch, solve_forest


def _random_ragged(rng, n_lo=1, n_hi=24, max_span=None):
    n = int(rng.integers(n_lo, n_hi + 1))
    parent = np.full(n, DEST, np.int32)
    for v in range(1, n):
        lo = 0 if max_span is None else max(0, v - max_span)
        parent[v] = int(rng.integers(lo, v))
    rho = rng.integers(1, 32, size=n) / 8.0          # dyadic: f32-exact
    t = Tree(parent, rho)
    load = rng.integers(0, 7, size=n)
    avail = rng.random(n) < 0.7
    return t, load, avail


def _check_batch(trees, loads, avails, k):
    res = solve_batch(trees, loads, k, avails)
    for b, t in enumerate(trees):
        want = soar(t, loads[b], k, avail=avails[b]).cost
        blue = res.blue_of(b)
        assert res.costs[b] == want                  # exact (dyadic rho)
        assert phi(t, loads[b], blue) == want        # mask realizes optimum
        assert blue.sum() <= k
        assert not np.any(blue & ~avails[b])
    return res


# ---------------------------------------------------------------------------
# solve_batch vs soar: >= 50 random ragged instances, exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,k", [(0, 0), (1, 1), (2, 3), (3, 5)])
def test_parity_random_ragged(seed, k):
    rng = np.random.default_rng(seed)
    trees, loads, avails = [], [], []
    for _ in range(15):                              # 4 params x 15 = 60 > 50
        t, load, avail = _random_ragged(rng)
        trees.append(t)
        loads.append(load)
        avails.append(avail)
    _check_batch(trees, loads, avails, k)


def test_parity_degenerate_shapes():
    """Chains, stars, singletons and mixed heights in one batch."""
    rng = np.random.default_rng(7)
    trees, loads, avails = [], [], []
    # singleton
    trees.append(Tree(np.array([DEST]), np.array([0.5])))
    # chain of 9
    trees.append(Tree(np.arange(-1, 8, dtype=np.int32),
                      rng.integers(1, 16, 9) / 8.0))
    # star: root with 11 leaves
    trees.append(Tree(np.array([DEST] + [0] * 11, np.int32),
                      rng.integers(1, 16, 12) / 8.0))
    # deep-ish random
    t, _, _ = _random_ragged(rng, n_lo=16, n_hi=20, max_span=2)
    trees.append(t)
    for t in trees:
        loads.append(rng.integers(0, 7, size=t.n))
        avails.append(rng.random(t.n) < 0.8)
    for k in (0, 2, 4):
        _check_batch(trees, loads, avails, k)


def test_masks_match_serial_on_bt():
    """On BT with power-law loads the engine reproduces soar_fast's masks
    bit-for-bit (same tables, same tie-breaking)."""
    t = bt(64, "constant")
    loads = [sample_load(t, "power-law", seed=s) for s in range(8)]
    res = solve_batch([t] * 8, loads, 6)
    for b, L in enumerate(loads):
        ref = soar_fast(t, L, 6)
        assert res.costs[b] == ref.cost
        assert np.array_equal(res.blue_of(b), ref.blue)


def test_zero_load_and_unavailable_everything():
    t = bt(16, "constant")
    zero = np.zeros(t.n, np.int64)
    none_avail = np.zeros(t.n, bool)
    res = solve_batch([t, t], [zero, sample_load(t, "uniform", seed=0)],
                      3, [None, none_avail])
    assert res.costs[0] == 0.0                       # nothing to send
    ref = soar(t, sample_load(t, "uniform", seed=0), 3, avail=none_avail)
    assert res.costs[1] == ref.cost                  # forced all-red
    assert res.blue_of(1).sum() == 0


def test_costs_only_mode():
    t = bt(32, "constant")
    loads = [sample_load(t, "power-law", seed=s) for s in range(4)]
    f = build_forest([t] * 4, loads)
    res = solve_forest(f, 4, options=EngineOptions(color=False))
    assert res.blue is None
    with pytest.raises(ValueError):
        res.blue_of(0)
    for b, L in enumerate(loads):
        assert res.costs[b] == soar(t, L, 4).cost


def test_pallas_and_fused_paths_agree():
    rng = np.random.default_rng(11)
    trees, loads, avails = [], [], []
    for _ in range(5):
        t, load, avail = _random_ragged(rng, n_hi=14)
        trees.append(t)
        loads.append(load)
        avails.append(avail)
    a = solve_batch(trees, loads, 2, avails,
                    options=EngineOptions(use_pallas=True, interpret=True))
    b = solve_batch(trees, loads, 2, avails,
                    options=EngineOptions(use_pallas=False))
    assert np.array_equal(a.costs, b.costs)
    assert np.array_equal(a.blue, b.blue)


def test_negative_budget_rejected():
    t = bt(16, "constant")
    with pytest.raises(ValueError):
        solve_batch([t], [sample_load(t, "uniform", seed=0)], -1)


def test_default_path_is_mask_and_cost_only():
    """The serving path must never pull DP tables off-device."""
    t = bt(32, "constant")
    loads = [sample_load(t, "power-law", seed=s) for s in range(4)]
    res = solve_batch([t] * 4, loads, 4)
    assert res.tables is None
    assert res.bytes_to_host == res.blue.nbytes + 4 * 4   # masks + f32 costs


@pytest.mark.slow
def test_engine_throughput_b64_meets_bars():
    """B=64 acceptance: device-resident solve >= 2x the PR 1 path and
    >= 5x the serial loop (the asserts live inside the benchmark).
    Steady-state margins are ~2.5x / ~20x; one retry absorbs scheduler
    noise when this runs late in a long suite."""
    from benchmarks.engine_throughput import run
    try:
        run(batches=(64,), reps=3, quiet=True)
    except AssertionError:
        run(batches=(64,), reps=3, quiet=True)


# ---------------------------------------------------------------------------
# Forest layout invariants
# ---------------------------------------------------------------------------

def test_forest_packed_layout_roundtrip():
    rng = np.random.default_rng(3)
    trees, loads = [], []
    for _ in range(6):
        t, load, _ = _random_ragged(rng)
        trees.append(t)
        loads.append(load)
    f = build_forest(trees, loads)
    assert f.n_slots >= max(t.n for t in trees)
    for b, t in enumerate(trees):
        # subtree-size prefix data backs the engine's per-level budget cap
        assert np.array_equal(f.sub_size[b, : t.n], t.subtree_sizes())
        assert f.sub_size[b, t.n :].sum() == 0
        # slot_of / slot_node are inverse on real nodes
        for v in range(t.n):
            s = f.slot_of[b, v]
            assert f.slot_node[b, s] == v
            # slot sits inside its depth's level block; internal sub-block
            d = t.depth[v]
            o, wi = f.lvl_off[d], f.lvl_internal[d]
            if t.children[v]:
                assert o <= s < o + wi
            else:
                assert o + wi <= s < o + f.lvl_width[d]
        # packed child pointers resolve to the children's slots
        for v in range(t.n):
            s = f.slot_of[b, v]
            ch = [c for c in f.pk_kid[b, s] if c < f.n_slots]
            assert sorted(ch) == sorted(f.slot_of[b, c]
                                        for c in t.children[v])


def test_forest_validates_shapes():
    t = bt(16, "constant")
    with pytest.raises(ValueError):
        build_forest([], [])
    with pytest.raises(ValueError):
        build_forest([t], [])
    with pytest.raises(ValueError):
        build_forest([t], [np.zeros(3, np.int64)])
