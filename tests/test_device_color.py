"""On-device SOAR-Color vs host color_batch vs serial soar — bit-identical.

The device traceback re-derives every budget split from the resident DP
tables with the serial solver's exact tie-breaking, so its blue masks must
equal the host replay and the serial reference *bit-for-bit*, not
approximately. Instances use dyadic rho (multiples of 1/8) so the engine's
float32 tables agree exactly with the float64 references (see
engine/batched.py numerics note).
"""
import numpy as np

from repro.core.forest import build_forest, layout_key
from repro.core.soar import soar
from repro.core.tree import DEST, Tree
from repro.engine import (EngineOptions, cache_stats, color_batch,
                          gather_batch, solve_forest)
from repro.testing import given, settings, st


@st.composite
def forest_instances(draw, max_b=4, max_n=14):
    """Ragged random forests with dyadic rates and partial availability."""
    B = draw(st.integers(1, max_b))
    trees, loads, avails = [], [], []
    for _ in range(B):
        n = draw(st.integers(1, max_n))
        parent = [DEST] + [draw(st.integers(0, v - 1)) for v in range(1, n)]
        rho = [draw(st.integers(1, 31)) / 8.0 for _ in range(n)]  # dyadic
        trees.append(Tree(np.array(parent), np.array(rho)))
        loads.append(np.array([draw(st.integers(0, 6)) for _ in range(n)],
                              np.int64))
        avails.append(np.array([draw(st.booleans()) for _ in range(n)],
                               bool))
    return trees, loads, avails


@settings(max_examples=12, deadline=None)
@given(forest_instances())
def test_device_color_bit_identical(inst):
    """k in {0, 1, n}: device masks == host color_batch == serial soar."""
    trees, loads, avails = inst
    n_max = max(t.n for t in trees)
    f = build_forest(trees, loads, avails)
    for k in sorted({0, 1, n_max}):
        dev = solve_forest(f, k)
        host = solve_forest(f, k, options=EngineOptions(debug_tables=True))
        assert np.array_equal(dev.blue, host.blue)       # bit-identical
        assert np.array_equal(dev.costs, host.costs)
        for b, t in enumerate(trees):
            ref = soar(t, loads[b], k, avail=avails[b])
            assert np.array_equal(dev.blue_of(b), ref.blue)
            assert dev.costs[b] == ref.cost


def test_budget_cap_is_exact():
    """Capped (per-level truncated) and uncapped gathers agree bit-for-bit."""
    rng = np.random.default_rng(17)
    trees, loads, avails = [], [], []
    for _ in range(6):
        n = int(rng.integers(2, 20))
        parent = np.full(n, DEST, np.int32)
        for v in range(1, n):
            parent[v] = int(rng.integers(0, v))
        trees.append(Tree(parent, rng.integers(1, 32, size=n) / 8.0))
        loads.append(rng.integers(0, 7, size=n))
        avails.append(rng.random(n) < 0.6)
    f = build_forest(trees, loads, avails)
    for k in (1, 4, 9):
        capped = solve_forest(f, k, options=EngineOptions(cap=True))
        full = solve_forest(f, k, options=EngineOptions(cap=False))
        assert np.array_equal(capped.costs, full.costs)
        assert np.array_equal(capped.blue, full.blue)


def test_debug_tables_escape_hatch():
    """debug_tables=True reproduces the PR 1 path: full tables on host,
    host-numpy color, and a correspondingly larger device->host bill."""
    rng = np.random.default_rng(3)
    n, B, k = 22, 5, 4
    parent = np.full(n, DEST, np.int32)
    for v in range(1, n):
        parent[v] = int(rng.integers(0, v))
    t = Tree(parent, rng.integers(1, 32, size=n) / 8.0)
    loads = [rng.integers(0, 7, size=n) for _ in range(B)]
    f = build_forest([t] * B, loads)
    dbg = solve_forest(f, k, options=EngineOptions(debug_tables=True))
    dev = solve_forest(f, k)
    # the hatch exposes node-indexed tables identical to gather_batch, and
    # host color over them equals the device traceback
    assert dbg.tables is not None
    assert dbg.tables.shape == (B, f.n_max + 1, f.h_max + 2, k + 1)
    np.testing.assert_array_equal(dbg.tables, gather_batch(f, k))
    assert np.array_equal(color_batch(f, dbg.tables, k), dev.blue)
    assert np.array_equal(dbg.blue, dev.blue)
    # the default path never pulls tables: masks + costs only
    assert dev.tables is None
    assert dev.bytes_to_host == dev.blue.nbytes + 4 * B   # masks + f32 costs
    assert dbg.bytes_to_host > 16 * dev.bytes_to_host


def test_layout_bucketing_collapses_jit_keys():
    """Ragged star fleets share bucketed layouts (and hence jit entries)."""
    def star(m):
        return Tree(np.array([DEST] + [0] * m, np.int32), np.ones(m + 1))

    bucketed, exact = set(), set()
    for m in range(3, 9):
        tr, load = star(m), np.r_[np.zeros(1, np.int64), np.ones(m, np.int64)]
        bucketed.add(layout_key(build_forest([tr], [load])))
        exact.add(layout_key(build_forest([tr], [load], bucket=False)))
    assert len(exact) == 6                    # every star is its own layout
    assert len(bucketed) < len(exact)         # buckets collapse the fleet
    stats = cache_stats()
    assert stats["forests_built"] >= 12
    assert 0 < stats["distinct_layouts"] <= stats["forests_built"]
    # solving two different-m stars through one bucketed layout still gives
    # per-instance exact results
    for m in (5, 7):
        load = np.r_[np.zeros(1, np.int64), np.ones(m, np.int64)]
        res = solve_forest(build_forest([star(m)], [load]), 2)
        ref = soar(star(m), load, 2)
        assert res.costs[0] == ref.cost
        assert np.array_equal(res.blue_of(0), ref.blue)
