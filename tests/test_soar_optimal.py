"""Optimality of SOAR (Theorem 4.1): exhaustive comparison vs brute force."""
import numpy as np
import pytest

from repro.core.brute import brute_force
from repro.core.reduce import phi
from repro.core.soar import soar
from repro.core.tree import DEST, Tree, bt, random_tree, sample_load, with_rates


def _check(t, load, k, avail=None):
    _, want = brute_force(t, load, k, avail=avail)
    res = soar(t, load, k, avail=avail)
    got_sim = phi(t, load, res.blue)
    assert res.blue.sum() <= k
    if avail is not None:
        assert not np.any(res.blue & ~np.asarray(avail, bool))
    np.testing.assert_allclose(res.cost, want, rtol=1e-12)
    np.testing.assert_allclose(got_sim, want, rtol=1e-12)


@pytest.mark.parametrize("k", [0, 1, 2, 3, 7])
@pytest.mark.parametrize("scheme", ["constant", "linear", "exponential"])
def test_bt8_all_k_all_rates(k, scheme):
    t = bt(8, scheme)
    load = sample_load(t, "power-law", seed=k)
    _check(t, load, k)


@pytest.mark.parametrize("seed", range(8))
def test_random_trees_random_rates(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    t = random_tree(n, seed=seed)
    load = rng.integers(0, 8, size=n)  # loads anywhere incl. internal, zeros
    k = int(rng.integers(0, 4))
    _check(t, load, k)


@pytest.mark.parametrize("seed", range(4))
def test_restricted_availability(seed):
    rng = np.random.default_rng(100 + seed)
    t = bt(16)
    load = sample_load(t, "uniform", seed=seed)
    avail = rng.random(t.n) < 0.5
    _check(t, load, 2, avail=avail)


def test_path_graph_chain_dependencies():
    """Paths stress the sequence-of-red-nodes long-range effect (Sec. 4)."""
    n = 7
    parent = np.arange(-1, n - 1, dtype=np.int32)  # 0 <- 1 <- 2 ...
    t = Tree(parent, np.linspace(0.3, 2.0, n))
    load = np.array([0, 3, 0, 5, 0, 2, 4])
    for k in range(4):
        _check(t, load, k)


def test_star_graph():
    n = 9
    parent = np.full(n, 0, dtype=np.int32)
    parent[0] = DEST
    t = Tree(parent, np.linspace(0.5, 1.5, n))
    load = np.arange(n)
    for k in range(3):
        _check(t, load, k)


def test_zero_load_subtree_sends_nothing():
    # A blue node over an empty subtree must not be charged a message.
    parent = np.array([DEST, 0, 0, 1, 1])
    t = Tree(parent, np.ones(5))
    load = np.array([0, 0, 5, 0, 0])  # left subtree fully empty
    _check(t, load, 2)


def test_larger_instance_vs_brute():
    t = bt(16, "linear")
    load = sample_load(t, "power-law", seed=7)
    _check(t, load, 3)
