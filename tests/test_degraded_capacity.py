"""Partial-capacity degradation: cost model, spill programs, orchestrator.

The execution-layer fault-tolerance path of PR 9: per-switch capacity
scales a(s) in [0, 1] (``ClusterTopology.cap_scale``), the degraded
reduce programs that spill a degraded blue switch's overflow one hop up
with *bit-identical* results to the fault-free fold, and the
orchestrator's two-stage ``on_switch_degrade`` recovery.
"""
import dataclasses

import numpy as np
import pytest

from repro.collectives import (build_fleet, build_program, chip_level_tree,
                               degrade_switches, fail_devices, fleet_tree,
                               plan, plan_batch, plan_congestion, plan_fleet)
from repro.collectives.schedule import (CompactOp, CompressOp, FoldOp,
                                        PermuteRound)
from repro.core.reduce import (agg_width, messages_up, messages_up_degraded,
                               phi, phi_degraded)
from repro.runtime import (ChaosReport, Orchestrator, OrchestratorConfig)


# -- cost model ---------------------------------------------------------------

def test_agg_width():
    assert agg_width(5, 1.0) == 5              # pristine folds everything
    assert agg_width(5, 2.0) == 5
    assert agg_width(4, 0.5) == 2
    assert agg_width(5, 0.5) == 3              # ceil
    assert agg_width(8, 0.01) == 1             # never below one partial
    assert agg_width(1, 0.01) == 1             # single message: no spill
    assert agg_width(0, 0.5) == 0


def test_messages_up_degraded_matches_pristine_when_unscaled():
    topo = fleet_tree(2, 2, 4)
    t = topo.tree
    rng = np.random.default_rng(0)
    for _ in range(5):
        blue = rng.random(t.n) < 0.4
        base = messages_up(t, topo.load, blue)
        assert np.array_equal(
            messages_up_degraded(t, topo.load, blue, None), base)
        assert np.array_equal(
            messages_up_degraded(t, topo.load, blue, np.ones(t.n)), base)
        assert phi_degraded(t, topo.load, blue) == phi(t, topo.load, blue)


def test_messages_up_degraded_spills_overflow_locally():
    # two pods of two racks: degrade one blue rack switch, check only its
    # own up-edge carries extra messages and everything above is pristine
    topo = fleet_tree(2, 2, 4)
    t = topo.tree
    blue = np.zeros(t.n, bool)
    rack = int(np.nonzero(topo.load > 1)[0][0])
    blue[rack] = True
    base = messages_up(t, topo.load, blue)
    w = int(topo.load[rack])                   # leaf blue: w = its load
    scale = np.ones(t.n)
    scale[rack] = 0.5
    deg = messages_up_degraded(t, topo.load, blue, scale)
    spill = w - agg_width(w, 0.5)
    assert deg[rack] == base[rack] + spill
    others = [v for v in range(t.n) if v != rack]
    assert np.array_equal(deg[others], base[others])
    # the premium is exactly the overflow traffic on the degraded up-edge
    assert phi_degraded(t, topo.load, blue, scale) == pytest.approx(
        phi(t, topo.load, blue) + spill * t.rho[rack])
    # shape validation
    with pytest.raises(ValueError, match="cap_scale shape"):
        messages_up_degraded(t, topo.load, blue, np.ones(3))


# -- topology plumbing --------------------------------------------------------

def test_degrade_switches_validates_and_composes():
    topo = fleet_tree(2, 2, 4)
    n = topo.tree.n
    t2 = degrade_switches(topo, {1: 0.5, 3: 0.25})
    assert t2.cap_scale[1] == 0.5 and t2.cap_scale[3] == 0.25
    assert t2.cap_scale[0] == 1.0
    # composition multiplies (a second partial loss on the same plane)
    t3 = degrade_switches(t2, {1: 0.5})
    assert t3.cap_scale[1] == 0.25
    for bad in ({-1: 0.5}, {n: 0.5}, {0: -0.1}, {0: 1.5},
                {0: float("nan")}, {0: float("inf")}):
        with pytest.raises(ValueError):
            degrade_switches(topo, bad)
    # tree, loads, rho untouched: capacity loss is not a link/load event
    assert np.array_equal(t2.load, topo.load)
    assert np.array_equal(t2.tree.rho, topo.tree.rho)


def test_zero_scale_composes_with_blocked_semantics():
    topo = fleet_tree(2, 2, 4)
    dead = degrade_switches(topo, {2: 0.0})
    cand = dead.candidates()
    assert not cand[2] and cand.sum() == topo.tree.n - 1
    blue = np.zeros(topo.tree.n, bool)
    blue[2] = True
    with pytest.raises(ValueError, match="zero-capacity"):
        build_program(dead, blue)
    # planners route around it, exactly like a blocked switch
    b, _ = plan(dead, 3)
    assert not b[2]
    (tp,) = plan_batch([dead], 3)
    assert not tp.blue[2]


def test_fail_devices_preserves_cap_scale():
    topo = degrade_switches(fleet_tree(2, 2, 4), {1: 0.5})
    t2 = fail_devices(topo, [0, 1])
    assert t2.cap_scale is not None and t2.cap_scale[1] == 0.5


# -- degraded programs: cost accounting + bitwise identity --------------------

def _run_host(prog, x):
    """Numpy interpreter mirroring the executor's arithmetic exactly
    (float32 strict sequential left folds)."""
    n_dev, d = x.shape
    buf = np.zeros((n_dev, prog.n_slots, d), np.float32)
    buf[:, 0] = x
    for op in prog.ops:
        if isinstance(op, PermuteRound):
            old = buf.copy()
            for (s, dst) in op.perm:
                off = int(op.recv_offset[dst])
                cnt = int(op.recv_count[dst])
                buf[dst, off:off + cnt] += old[s, :cnt]
        elif isinstance(op, CompressOp):
            for dev in range(n_dev):
                if op.flag[dev]:
                    w = int(op.width[dev])
                    acc = buf[dev, 0].copy()
                    for j in range(1, w):
                        acc = acc + buf[dev, j]
                    buf[dev, 1:w] = 0
                    buf[dev, 0] = acc
        elif isinstance(op, FoldOp):
            for dev in range(n_dev):
                cnt = int(op.count[dev])
                if cnt > 0:
                    st = int(op.start[dev])
                    acc = buf[dev, st].copy()
                    for j in range(1, cnt):
                        acc = acc + buf[dev, st + j]
                    buf[dev, st] = acc
        else:  # CompactOp
            old = buf.copy()
            for dev in range(n_dev):
                for i, srci in enumerate(op.src[dev]):
                    buf[dev, i] = old[dev, srci] if srci >= 0 else 0
    acc = buf[prog.root_home, 0].copy()
    for j in range(1, prog.root_count):
        acc = acc + buf[prog.root_home, j]
    return acc


def test_degraded_program_cost_accounting():
    topo = chip_level_tree(2, 2, 2)
    t = topo.tree
    rng = np.random.default_rng(3)
    for _ in range(10):
        blue = rng.random(t.n) < 0.5
        scales = {int(s): float(rng.choice([0.75, 0.5, 0.25]))
                  for s in rng.choice(t.n, size=2, replace=False)}
        td = degrade_switches(topo, scales)
        prog = build_program(td, blue)
        assert prog.utilization == phi_degraded(t, td.load, blue,
                                                td.cap_scale)
        assert prog.total_network_messages == int(
            messages_up_degraded(t, td.load, blue, td.cap_scale).sum())
        assert prog.utilization >= build_program(topo, blue).utilization


def test_degraded_program_bitwise_identical_to_pristine():
    """The load-bearing claim: a degraded switch's spill completes at its
    parent's host with the SAME summation order, so gradients are
    bit-identical to the fault-free reduce."""
    rng = np.random.default_rng(1)
    total = 0
    for dims in [(1, 2, 2), (2, 2, 2), (1, 4, 2), (2, 2, 4)]:
        topo = chip_level_tree(*dims)
        t = topo.tree
        x = rng.standard_normal((topo.n_devices, 3)).astype(np.float32)
        for _ in range(12):
            blue = rng.random(t.n) < 0.5
            ref = _run_host(build_program(topo, blue), x)
            np.testing.assert_allclose(ref, x.sum(0), atol=1e-4)
            ks = rng.choice(t.n, size=int(rng.integers(1, 4)),
                            replace=False)
            scales = {int(s): float(rng.choice(
                [0.9, 0.75, 0.5, 0.25, 0.1, 0.01])) for s in ks}
            td = degrade_switches(topo, scales)
            pd = build_program(td, blue)
            got = _run_host(pd, x)
            assert got.tobytes() == ref.tobytes(), (dims, scales)
            total += 1
    assert total >= 40


def test_degraded_root_spill_completes_at_destination():
    topo = chip_level_tree(2, 2, 2)
    t = topo.tree
    blue = np.ones(t.n, bool)
    rng = np.random.default_rng(5)
    x = rng.standard_normal((8, 4)).astype(np.float32)
    ref = _run_host(build_program(topo, blue), x)
    w = len(t.children[t.root])                # all-blue: one msg per child
    for f in (0.6, 0.3, 0.05):
        td = degrade_switches(topo, {int(t.root): f})
        pd = build_program(td, blue)
        # the root's overflow rides to the destination as extra messages
        assert pd.root_count == 1 + (w - agg_width(w, f))
        assert _run_host(pd, x).tobytes() == ref.tobytes()
    assert build_program(
        degrade_switches(topo, {int(t.root): 0.05}), blue).root_count > 1


# -- planner capacity snapshots ----------------------------------------------

def test_plan_congestion_scales_capacity_snapshot():
    """A degraded topology with capacity C must plan exactly like the
    pristine one with the pre-scaled snapshot C * cap_scale — the
    capacity the pricing loop sees is the *effective* one."""
    topo = fleet_tree(2, 2, 4)
    n = topo.tree.n
    deg = degrade_switches(topo, {v: 0.25 for v in range(n)})
    kw = dict(max_rounds=3, cap_beta=2.0, cap_frac=0.5)
    got = plan_congestion(deg, 3, count=4, capacity=np.full(n, 4.0), **kw)
    want = plan_congestion(topo, 3, count=4, capacity=np.full(n, 1.0), **kw)
    for a, b in zip(got.plans, want.plans, strict=True):
        assert np.array_equal(a.blue, b.blue)


def test_plan_fleet_scales_per_tree_capacity():
    fleet = build_fleet(2, 2, 2, 2)
    n0 = fleet.topos[0].tree.n
    n1 = fleet.topos[1].tree.n
    deg = dataclasses.replace(
        fleet, topos=(degrade_switches(fleet.topos[0],
                                       {v: 0.25 for v in range(n0)}),
                      fleet.topos[1]))
    caps = [np.full(n0, 4.0), np.full(n1, 4.0)]
    kw = dict(max_rounds=3, cap_beta=2.0, cap_frac=0.5)
    got = plan_fleet(deg, 2, counts=[2, 2], capacity=caps, **kw)
    want = plan_fleet(fleet, 2, counts=[2, 2],
                      capacity=[caps[0] * 0.25, caps[1]], **kw)
    for a, b in zip(got.plans, want.plans, strict=True):
        assert np.array_equal(a.blue, b.blue)
    assert np.array_equal(got.tree_of, want.tree_of)


# -- orchestrator two-stage recovery -----------------------------------------

def mk(k=3, capacity=None):
    topo = chip_level_tree(n_pods=2, racks_per_pod=3, chips_per_rack=2)
    return topo, Orchestrator(topo, OrchestratorConfig(k=k,
                                                       capacity=capacity))


def test_on_switch_degrade_two_stage_and_cached_restore():
    topo, orch = mk(k=3)
    u0 = orch.program.utilization
    s = int(np.nonzero(orch.blue)[0][0])
    orch.on_switch_degrade({s: 0.5})
    ev = orch.degraded_events[-1]
    assert ev["switches"] == (s,) and ev["scales"] == (0.5,)
    # stage 1 exists and is a bounded regression, stage 2 never worse
    assert ev["degraded_utilization"] >= u0
    assert ev["utilization"] <= ev["degraded_utilization"]
    assert not ev["cache_hit"]                 # first time: honest solve
    # restoring the plane is a fingerprint-keyed cache lookup
    orch.on_switch_degrade({s: 1.0})
    ev2 = orch.degraded_events[-1]
    assert ev2["cache_hit"]
    assert orch.program.utilization == u0
    assert (orch._switch_scale == 1.0).all()


def test_on_switch_degrade_zero_forces_blue_off():
    topo, orch = mk(k=3)
    s = int(np.nonzero(orch.blue)[0][0])
    orch.on_switch_degrade({s: 0.0})
    assert not orch.blue[s]
    assert orch.degraded_events[-1]["was_blue"] == (s,)


def test_on_switch_degrade_validates_before_mutating():
    topo, orch = mk(k=3)
    n = topo.tree.n
    state = orch._switch_scale.copy()
    for bad in ({n: 0.5}, {-1: 0.5}, {0: -0.1}, {0: 1.5},
                {0: float("nan")}, {1.5: 0.5}):
        with pytest.raises(ValueError):
            orch.on_switch_degrade(bad)
        assert np.array_equal(orch._switch_scale, state)


def test_on_switch_degrade_ledger_eviction():
    topo, orch = mk(k=3, capacity=2)
    orch.begin_workloads(2)                    # foreign claims on switches
    s = int(np.nonzero(orch.blue)[0][0])
    orch.on_switch_degrade({s: 0.25})          # floor(2 * 0.25) = 0 units
    ev = orch.degraded_events[-1]
    assert ev["capacity_delta"] == -2
    assert s in ev["was_blue"] or ev["evicted_foreign"] > 0
    assert (orch._residual >= 0).all()
    assert not orch.blue[s]                    # own blue evicted first


def test_fingerprint_distinguishes_capacity_states():
    topo, orch = mk(k=3)
    s = int(np.nonzero(orch.blue)[0][0])
    fp0 = orch._fingerprint()
    orch.on_switch_degrade({s: 0.5})
    assert orch._fingerprint() != fp0
    orch.on_switch_degrade({s: 1.0})
    assert orch._fingerprint() == fp0


def test_on_rescale_resets_switch_scale():
    topo, orch = mk(k=3)
    orch.on_switch_degrade({1: 0.5})
    orch.on_rescale(n_pods=2, racks_per_pod=2, chips_per_rack=2)
    assert (orch._switch_scale == 1.0).all()
    assert orch.topo.cap_scale is None or (orch.topo.cap_scale == 1.0).all()


# -- satellite regressions ----------------------------------------------------

def test_on_link_degrade_validates_rates():
    topo, orch = mk(k=3)
    n = topo.tree.n
    state = orch._link_rate.copy()
    for bad in ({n: 0.5}, {-1: 0.5}, {0: 0.0}, {0: -1.0},
                {0: float("nan")}, {0: float("inf")}, {2.5: 0.5}):
        with pytest.raises(ValueError):
            orch.on_link_degrade(bad)
        assert np.array_equal(orch._link_rate, state)


def test_events_per_sec_zero_duration_guard():
    rep = ChaosReport(records=[], events=5, replans=0, cache_hits=0,
                      stale=0, invariant_checks=5, seconds=0.0)
    assert rep.events_per_sec == 0.0
    rep2 = ChaosReport(records=[], events=10, replans=0, cache_hits=0,
                       stale=0, invariant_checks=10, seconds=2.0)
    assert rep2.events_per_sec == 5.0
