"""Fault-tolerance runtime: failure -> re-place, stragglers, elastic, capacity."""
import numpy as np
import pytest

from repro.collectives import fleet_tree
from repro.collectives.schedule import plan
from repro.core.reduce import phi
from repro.runtime import Orchestrator, OrchestratorConfig, StragglerPolicy
from repro.runtime.elastic import rescale, scaling_budget, shrink_by_failure


def mk(k=4, capacity=None, **kw):
    topo = fleet_tree(n_pods=2, racks_per_pod=4, chips_per_rack=4)
    return topo, Orchestrator(topo, OrchestratorConfig(k=k, capacity=capacity,
                                                       **kw))


def test_initial_plan_is_soar_optimal():
    topo, orch = mk(k=4)
    from repro.core.soar import soar
    assert orch.program.utilization == pytest.approx(
        soar(topo.tree, topo.load, 4).cost)


def test_failure_triggers_replan_and_lowers_load():
    topo, orch = mk(k=4)
    u0 = orch.program.utilization
    orch.on_failure([0, 1, 2, 3])           # kill one whole rack
    assert orch.n_alive == 28
    assert orch.replans == 2
    # utilization of the new plan is for the reduced load -> strictly less
    assert orch.program.utilization < u0
    # the new placement is optimal for the degraded topology
    from repro.core.soar import soar
    assert orch.program.utilization == pytest.approx(
        soar(orch.topo.tree, orch.topo.load, 4).cost)


def test_failure_then_recover_restores_plan():
    topo, orch = mk(k=4)
    u0 = orch.program.utilization
    orch.on_failure([5])
    orch.on_recover([5])
    assert orch.n_alive == topo.n_devices
    assert orch.program.utilization == pytest.approx(u0)


def test_all_devices_failing_raises():
    topo, orch = mk(k=2)
    with pytest.raises(RuntimeError):
        orch.on_failure(list(range(topo.n_devices)))


def test_double_failure_raises():
    topo, orch = mk(k=2)
    orch.on_failure([3])
    with pytest.raises(ValueError):
        orch.on_failure([3])


def test_grad_scale_renormalizes():
    topo, orch = mk(k=2)
    assert orch.grad_scale == 1.0
    orch.on_failure([0, 1])
    assert orch.grad_scale == pytest.approx(32 / 30)


def test_straggler_quarantine_and_replan():
    topo, orch = mk(k=4, straggler_patience=2)
    base = np.full(topo.n_devices, 1.0)
    slow = base.copy()
    slow[7] = 10.0                        # device 7 is persistently slow
    r1 = orch.on_step_durations(slow)
    assert r1.suspects[7] and not r1.quarantined[7]
    r2 = orch.on_step_durations(slow)
    assert r2.quarantined[7]
    assert orch.quarantined[7]
    assert orch.n_alive == topo.n_devices - 1
    assert orch.replans == 2              # init + quarantine replan
    # recovery clears quarantine
    orch.on_recover([7])
    assert orch.n_alive == topo.n_devices


def test_straggler_policy_no_false_positive_on_uniform():
    pol = StragglerPolicy(16, patience=2)
    for _ in range(5):
        rep = pol.observe(np.random.default_rng(0).uniform(0.9, 1.1, 16))
        assert not rep.quarantined.any()


def test_capacity_respected_across_workloads():
    topo, orch = mk(k=4, capacity=1)
    first = orch.blue.copy()
    prog2 = orch.begin_workload()         # second workload: capacity 1 used up
    # second workload cannot reuse any first-workload blue switch
    blue2_util = prog2.utilization
    assert blue2_util >= orch.utilization_history[0]  # strictly harder problem
    # manually verify disjointness by replaying the plan
    avail = orch._residual >= 0
    assert (orch._residual >= 0).all()


def test_preplan_failures_matches_serial_replan():
    """Batched what-if analysis == what a real failure would replan to."""
    topo, orch = mk(k=3)
    scenarios = [[0], [0, 1, 2, 3], [5, 9]]
    planned = orch.preplan_failures(scenarios)
    assert len(planned) == len(scenarios)
    for devices, (blue, util) in zip(scenarios, planned):
        probe = Orchestrator(topo, OrchestratorConfig(k=3))
        probe.on_failure(list(devices))
        assert util == pytest.approx(probe.program.utilization)
        assert blue.sum() <= 3
    # preplanning must not mutate the live orchestrator
    assert orch.replans == 1
    assert orch.n_alive == topo.n_devices


def test_preplan_failures_matches_serial_replan_with_capacity():
    """Under bounded capacity a real replan first releases this workload's
    own claim; preplanning must see the same availability."""
    topo, orch = mk(k=3, capacity=1)
    planned = orch.preplan_failures([[0], [4, 5]])
    residual_before = orch._residual.copy()
    for devices, (blue, util) in zip([[0], [4, 5]], planned):
        probe = Orchestrator(topo, OrchestratorConfig(k=3, capacity=1))
        probe.on_failure(list(devices))
        assert util == pytest.approx(probe.program.utilization)
    # still a read-only operation
    assert np.array_equal(orch._residual, residual_before)
    assert orch.replans == 1


def test_begin_workloads_batched_respects_capacity():
    topo, orch = mk(k=4, capacity=2)          # init claim uses 1 of 2
    progs = orch.begin_workloads(3)
    assert len(progs) == 3
    assert (orch._residual >= 0).all()
    # 4 total workloads admitted (init + 3)
    assert len(orch.utilization_history) == 4


def test_elastic_rescale_and_budget():
    topo = fleet_tree(2, 4, 4)
    bigger = rescale(topo, 4, 4, 4)
    assert bigger.n_devices == 64
    assert scaling_budget(4, topo.n_devices, bigger.n_devices) == 8
    assert scaling_budget(4, topo.n_devices, bigger.n_devices, "fixed") == 4
    smaller = shrink_by_failure(topo, [0, 1])
    assert smaller.load.sum() == topo.load.sum() - 2


def test_replan_is_bounded_by_budget_always():
    topo, orch = mk(k=3)
    rng = np.random.default_rng(1)
    alive = list(range(topo.n_devices))
    for _ in range(6):
        d = int(rng.choice(alive))
        alive.remove(d)
        orch.on_failure([d])
        assert orch.blue.sum() <= 3
        # placement only uses switches (never out of tree bounds)
        assert orch.program.utilization == pytest.approx(
            phi(orch.topo.tree, orch.topo.load, orch.blue))


def test_on_recover_never_failed_device_raises():
    """on_recover used to silently accept healthy devices and reset their
    straggler state — now symmetric with on_failure's already-dead check."""
    topo, orch = mk(k=2)
    with pytest.raises(ValueError):
        orch.on_recover([4])
    assert orch.replans == 1                  # no spurious replan happened
    orch.on_failure([4])
    orch.on_recover([4])                      # legitimate recovery still works
    # a mixed list with one bad id must not half-apply before raising
    orch.on_failure([5, 6])
    with pytest.raises(ValueError):
        orch.on_recover([5, 7])               # 7 is healthy
    assert not orch.alive[5] and not orch.alive[6]
    orch.on_recover([5, 6])
    assert orch.n_alive == topo.n_devices


def test_capacity_residual_never_negative_across_events():
    """Residual aggregation capacity stays >= 0 across batched admissions,
    failure replans and recoveries, and released claims add back up."""
    topo, orch = mk(k=4, capacity=2)
    total = orch._residual.sum() + orch.blue.sum()    # capacity invariant
    assert (orch._residual >= 0).all()
    orch.begin_workloads(2)                   # 3 workloads hold claims now
    assert (orch._residual >= 0).all()
    claimed_before = total - orch._residual.sum()
    orch.on_failure([0, 1, 2, 3])             # replan releases+reclaims own
    assert (orch._residual >= 0).all()
    orch.on_recover([0, 1, 2, 3])
    assert (orch._residual >= 0).all()
    # the failure/recovery cycle restores the original plan: claims must
    # balance back exactly — a leak here is the double-release bug class
    assert total - orch._residual.sum() == claimed_before
    orch.begin_workloads(1, congestion_aware=True)
    assert (orch._residual >= 0).all()
    assert total - orch._residual.sum() >= claimed_before  # new claim added


def test_preplan_snapshot_matches_real_replan_with_extra_workloads():
    """preplan_failures' claim-release snapshot must equal the availability
    a real replan sees, also when other workloads hold claims."""
    topo, orch = mk(k=3, capacity=2)
    orch.begin_workload()                     # a second tenant claims slots
    planned = orch.preplan_failures([[0], [4, 5]])
    for devices, (blue, util) in zip([[0], [4, 5]], planned):
        probe = Orchestrator(topo, OrchestratorConfig(k=3, capacity=2))
        probe.begin_workload()                # reproduce the claim state
        probe.on_failure(list(devices))
        assert util == pytest.approx(probe.program.utilization)
        assert blue.sum() <= 3
    # preplanning stays read-only
    assert orch.replans == 1
    assert (orch._residual >= 0).all()


def test_on_failure_validates_before_mutating():
    """A bad id mid-list must not half-apply: on_failure([ok, dead]) used to
    mark `ok` dead, then raise — leaving alive/grad_scale inconsistent with
    the still-compiled program."""
    topo, orch = mk(k=2)
    orch.on_failure([9])
    with pytest.raises(ValueError):
        orch.on_failure([10, 9])              # 9 already dead
    assert orch.alive[10]                     # 10 untouched by rejected call
    assert orch.n_alive == topo.n_devices - 1
    with pytest.raises(ValueError):
        orch.on_failure([11, topo.n_devices])  # out-of-range id
    assert orch.alive[11]
    # duplicates in one call collapse to a single failure
    orch.on_failure([12, 12])
    assert orch.n_alive == topo.n_devices - 2


def test_all_devices_failing_leaves_state_untouched():
    """The all-devices-failed RuntimeError must fire *before* mutation, not
    after marking everything dead with a stale compiled program."""
    topo, orch = mk(k=2)
    with pytest.raises(RuntimeError):
        orch.on_failure(list(range(topo.n_devices)))
    assert orch.n_alive == topo.n_devices     # nothing was half-applied
    assert orch.replans == 1
    orch.on_failure([0])                      # orchestrator still usable


def test_begin_workloads_zero_count_returns_empty():
    """count=0 is a no-op in both admission modes (the plain path already
    returned []; the congestion path used to crash in the driver)."""
    topo, orch = mk(k=2, capacity=2)
    assert orch.begin_workloads(0) == []
    assert orch.begin_workloads(0, congestion_aware=True) == []
    assert len(orch.utilization_history) == 1     # only the init plan


def test_straggler_quantile_masks_dead_devices():
    """The deadline quantile must run over *alive* devices only: a dead
    slow device's frozen EWMA used to inflate the cutoff forever, letting
    live stragglers sail under it."""
    pol = StragglerPolicy(8, quantile=0.6, slack=1.5, patience=1)
    alive = np.ones(8, bool)
    warm = np.ones(8)
    warm[5:] = 50.0                        # three persistently slow devices
    pol.observe(warm, alive=alive)
    alive[5:] = False                      # ... then they die
    later = np.ones(8)
    later[0] = 4.0                         # a live straggler appears
    rep = pol.observe(later, alive=alive)
    # with the dead profiles masked, the quantile sits at the fast level
    # and the live straggler is over the deadline
    assert rep.deadline < 4.0
    assert rep.suspects[0]
    assert not rep.suspects[5:].any()      # dead devices never suspects
    # unmasked observe (the old behavior) misses it: cutoff is inflated
    pol2 = StragglerPolicy(8, quantile=0.6, slack=1.5, patience=1)
    pol2.observe(warm)
    assert not pol2.observe(later).suspects[0]


def test_straggler_observe_empty_alive_is_noop():
    pol = StragglerPolicy(4, patience=1)
    rep = pol.observe(np.ones(4), alive=np.zeros(4, bool))
    assert not rep.suspects.any() and np.isinf(rep.deadline)


def test_on_step_durations_never_quarantines_last_devices():
    """on_failure refuses to kill the last alive device; on_step_durations
    must hold the same floor — by skipping the quarantine (telemetry is
    advisory), not by raising mid-training-step."""
    topo, orch = mk(k=2, straggler_patience=1)

    class _CondemnAll:
        def observe(self, durations, alive=None):
            from repro.runtime import StragglerReport
            return StragglerReport(suspects=alive.copy(),
                                   quarantined=alive.copy(), deadline=0.0)

    orch.stragglers = _CondemnAll()
    replans0 = orch.replans
    orch.on_step_durations(np.ones(topo.n_devices))
    assert orch.n_alive == topo.n_devices  # nothing quarantined
    assert orch.replans == replans0        # and no spurious replan


def test_rescale_derives_dims_from_topology():
    """rescale(topo, ...) used to ignore `topo` entirely and require all
    three dimensions; unspecified ones now come from the topology."""
    from repro.runtime import fleet_dims
    topo = fleet_tree(2, 4, 4)
    assert fleet_dims(topo) == (2, 4, 4)
    grown = rescale(topo, n_pods=3)
    assert fleet_dims(grown) == (3, 4, 4)
    assert grown.n_devices == 48
    fatter = rescale(topo, chips_per_rack=8)
    assert fleet_dims(fatter) == (2, 4, 8)
    assert rescale(topo, 4, 4, 4).n_devices == 64   # legacy spelling
    # ragged pods (one pod has a rack, the other none) are rejected
    from repro.collectives import ClusterTopology
    from repro.core.tree import DEST, Tree
    ragged = Tree(np.array([DEST, 0, 0, 1]), np.ones(4))
    bad = ClusterTopology(tree=ragged, device_leaf=np.array([3, 3]),
                          load=np.array([0, 0, 0, 2]))
    with pytest.raises(ValueError):
        fleet_dims(bad)


def test_on_rescale_replans_with_scaled_budget():
    topo, orch = mk(k=4, capacity=2)
    orch.on_failure([0])
    orch.begin_workload()                  # another tenant claims capacity
    prog = orch.on_rescale(n_pods=4)       # 2 -> 4 pods: fleet doubles
    assert orch.topo.n_devices == 64
    assert orch.cfg.k == 8                 # proportional budget
    assert orch.blue.sum() <= 8
    assert orch.n_alive == 64              # health state reset with fleet
    # drain semantics: only this workload's claim is live again
    total = orch._residual.sum() + orch.blue.sum()
    assert total == 2 * orch.topo.tree.n
    assert prog.utilization == pytest.approx(
        phi(orch.topo.tree, orch.topo.load, orch.blue))
    # fixed policy keeps k
    _, orch2 = mk(k=4, capacity=None)
    orch2.on_rescale(n_pods=4, budget_policy="fixed")
    assert orch2.cfg.k == 4
