"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import api
from repro.models.api import ShapeSpec

SMOKE_SHAPE = ShapeSpec("smoke", seq_len=32, global_batch=2, kind="train")


def _reduced(name):
    return ARCHS[name].reduced()


def _batch(cfg, kind="train"):
    rng = np.random.default_rng(0)
    spec = dataclasses.replace(SMOKE_SHAPE, kind=kind)
    zeros = api.input_specs(cfg, spec, mode=kind)

    def rnd(a):
        if a.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, cfg.vocab, a.shape), jnp.int32)
        return jnp.asarray(rng.normal(size=a.shape), a.dtype)

    return jax.tree.map(rnd, zeros)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    cfg = _reduced(name)
    params = api.init_fn(cfg)(jax.random.PRNGKey(0))
    batch = _batch(cfg, "train")
    loss, metrics = jax.jit(api.loss_fn(cfg))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (name, loss)
    grads = jax.grad(lambda p: api.loss_fn(cfg)(p, batch)[0])(params)
    finite = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    assert all(jax.tree.leaves(finite)), name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_then_decode_smoke(name):
    cfg = _reduced(name)
    params = api.init_fn(cfg)(jax.random.PRNGKey(0))
    batch = _batch(cfg, "prefill")
    logits, caches = jax.jit(api.prefill_fn(cfg))(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert jnp.all(jnp.isfinite(logits)), name
    # grow to a fixed-capacity decode cache and take two steps
    dec_caches = api.init_caches(cfg, batch=2, seq=64)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(api.decode_fn(cfg))
    logits1, dec_caches = step(params, dec_caches, tok, jnp.int32(0))
    logits2, dec_caches = step(params, dec_caches, tok, jnp.int32(1))
    assert logits1.shape == (2, 1, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits1)) and jnp.all(jnp.isfinite(logits2))


def test_param_counts_match_assignment_scale():
    """Analytic param counts are in the ballpark the arch names claim."""
    expect = {
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "deepseek-v2-236b": (1.9e11, 2.8e11),
        "granite-20b": (1.5e10, 2.5e10),
        "nemotron-4-340b": (3.0e11, 3.8e11),
        "qwen3-32b": (2.7e10, 3.9e10),
        "minicpm3-4b": (3.0e9, 5.0e9),
        "llava-next-34b": (2.8e10, 4.0e10),
        "xlstm-125m": (0.8e8, 2.2e8),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params():
    cfg = ARCHS["kimi-k2-1t-a32b"]
    active = cfg.active_param_count()
    assert 2.0e10 <= active <= 4.5e10  # ~32B active


def test_mla_decode_absorbed_equals_materialized():
    """The absorbed (latent) decode path must match materialized K/V."""
    cfg = _reduced("deepseek-v2-236b")
    cfg_m = dataclasses.replace(cfg, decode_absorb=False)
    params = api.init_fn(cfg)(jax.random.PRNGKey(1))
    caches_a = api.init_caches(cfg, 2, 16)
    caches_m = api.init_caches(cfg_m, 2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    la, _ = api.decode_fn(cfg)(params, caches_a, tok, jnp.int32(0))
    lm, _ = api.decode_fn(cfg_m)(params, caches_m, tok, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lm, np.float32), atol=2e-2)


def test_decode_matches_prefill_logits():
    """Greedy-path consistency: decoding token t reproduces prefill logits."""
    cfg = _reduced("qwen3-32b")
    params = api.init_fn(cfg)(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    logits_p, _ = api.prefill_fn(cfg)(params, {"tokens": toks})
    # decode token-by-token
    caches = api.init_caches(cfg, 1, 16)
    step = jax.jit(api.decode_fn(cfg))
    out = None
    for t in range(8):
        out, caches = step(params, caches, toks[:, t : t + 1], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(out[:, 0], np.float32),
        np.asarray(logits_p[:, 0], np.float32), atol=2e-2)
