"""Cross-workload budget allocation (paper §8 open problem)."""
import numpy as np
import pytest

from repro.core.budget import (allocate_budget, brute_allocate, cost_curve,
                               uniform_allocate)
from repro.core.soar import soar
from repro.core.tree import bt, random_tree, sample_load


def _workloads(t, n, seed=0):
    return [sample_load(t, "power-law" if i % 2 else "uniform",
                        seed=seed + i) for i in range(n)]


def test_cost_curve_matches_soar_pointwise():
    t = bt(32, "linear")
    L = sample_load(t, "power-law", seed=1)
    c = cost_curve(t, L, 6)
    for k in range(7):
        assert c[k] == pytest.approx(soar(t, L, k).cost)


def test_curve_monotone():
    t = bt(64, "constant")
    c = cost_curve(t, sample_load(t, "power-law", seed=2), 12)
    assert (np.diff(c) <= 1e-9).all()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_greedy_close_to_brute(seed):
    t = bt(16, "constant")
    ws = _workloads(t, 3, seed=10 * seed)
    K = 6
    b_g, c_g = allocate_budget(t, ws, K)
    b_b, c_b = brute_allocate(t, ws, K)
    assert b_g.sum() <= K
    assert c_g <= c_b * 1.02 + 1e-9          # near-exact on these instances
    assert c_b <= c_g + 1e-9                 # brute is the floor


def test_greedy_beats_uniform():
    t = bt(64, "exponential")
    # heterogeneous workloads: some heavy, some trivial
    ws = _workloads(t, 4, seed=5)
    ws[0] = ws[0] * 20                        # one workload dominates
    K = 12
    _, c_g = allocate_budget(t, ws, K)
    _, c_u = uniform_allocate(t, ws, K)
    assert c_g <= c_u + 1e-9


def test_budget_never_exceeded_and_zero_budget():
    t = bt(32, "constant")
    ws = _workloads(t, 5, seed=3)
    b, c = allocate_budget(t, ws, 0)
    assert b.sum() == 0
    b, _ = allocate_budget(t, ws, 7)
    assert b.sum() <= 7
