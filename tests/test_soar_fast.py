"""Equivalence of the vectorized / capped gathers with the reference DP."""
import numpy as np
import pytest

from repro.core.brute import brute_force
from repro.core.reduce import phi
from repro.core.soar import soar, soar_gather
from repro.core.soar_fast import soar_fast, soar_gather_vectorized
from repro.core.tree import bt, random_tree, rpa, sample_load


@pytest.mark.parametrize("seed", range(6))
def test_fast_equals_reference_random(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 24))
    t = random_tree(n, seed=seed)
    load = rng.integers(0, 7, size=n)
    k = int(rng.integers(0, 6))
    avail = rng.random(n) < 0.7
    ref = soar(t, load, k, avail=avail)
    fast = soar_fast(t, load, k, avail=avail)
    np.testing.assert_allclose(fast.cost, ref.cost, rtol=1e-12)
    np.testing.assert_allclose(phi(t, load, fast.blue), ref.cost, rtol=1e-12)


@pytest.mark.parametrize("scheme", ["constant", "linear", "exponential"])
def test_fast_bt64(scheme):
    t = bt(64, scheme)
    load = sample_load(t, "power-law", seed=3)
    for k in (0, 1, 4, 9):
        ref = soar(t, load, k)
        fast = soar_fast(t, load, k)
        np.testing.assert_allclose(fast.cost, ref.cost, rtol=1e-12)


def test_fast_scale_free():
    t = rpa(128, seed=5)
    load = sample_load(t, "ones", seed=0, leaves_only=False)
    for k in (1, 4, 8):
        ref = soar(t, load, k)
        fast = soar_fast(t, load, k)
        np.testing.assert_allclose(fast.cost, ref.cost, rtol=1e-12)


def test_capped_tables_match_uncapped():
    t = bt(32, "linear")
    load = sample_load(t, "uniform", seed=1)
    k = 6
    Xc = soar_gather(t, load, k, cap=True)
    Xu = soar_gather(t, load, k, cap=False)
    for v in range(t.n):
        np.testing.assert_allclose(Xc[v], Xu[v], rtol=1e-12)


def test_vectorized_tables_match_reference():
    t = bt(16)
    load = sample_load(t, "power-law", seed=2)
    k = 3
    Xr = soar_gather(t, load, k, cap=False)
    Xv = soar_gather_vectorized(t, load, k)
    for v in range(t.n):
        nl = t.depth[v] + 2
        np.testing.assert_allclose(Xv[v][:nl], Xr[v], rtol=1e-12)


def test_fast_vs_brute_small():
    rng = np.random.default_rng(42)
    for seed in range(4):
        n = int(rng.integers(3, 9))
        t = random_tree(n, seed=100 + seed)
        load = rng.integers(0, 6, size=n)
        k = int(rng.integers(0, 3))
        _, want = brute_force(t, load, k)
        got = soar_fast(t, load, k)
        np.testing.assert_allclose(got.cost, want, rtol=1e-12)
