"""Device-side hard admission vs the host ledger reference — bit parity —
plus multi-job preemption and admission telemetry.

The in-loop admission (``residual=``) truncates every round's candidate
blues to the claims an integer per-switch ledger covers. The device loop
applies the truncation as a rank-vs-residual mask inside the jitted
``lax.while_loop``; the host driver replays the ledger *literally* —
a sequential claim-by-claim walk in tenant order. Both are exact integer
arithmetic, so with ``record_rounds=True`` they must agree round for
round bitwise: same masks, same per-tenant dropped-claim counts, same
remaining ledgers (see the parity notes in ``engine/congestion.py``).
"""
import numpy as np
import pytest

from repro.collectives import build_fleet, fleet_tree, plan_congestion
from repro.core import bt
from repro.core.tree import sample_load
from repro.engine import solve_congestion, solve_fleet
from repro.runtime import (Orchestrator, OrchestratorConfig,
                           PreemptionPolicy)


def _fleet(n=64, T=8, scheme="constant"):
    t = bt(n, scheme)
    loads = [sample_load(t, "power-law", seed=100 + s) for s in range(T)]
    return t, loads


def _assert_bit_identical(dev, host):
    assert dev.history == host.history                  # f32 C_max, exact
    assert dev.rounds == host.rounds
    assert dev.best_round == host.best_round
    assert np.array_equal(dev.blue, host.blue)
    assert dev.max_congestion == host.max_congestion
    assert np.array_equal(dev.msgs, host.msgs)
    # the admission ledgers are integers — identical, not close
    assert np.array_equal(dev.admission_dropped, host.admission_dropped)
    for rg_d, rg_h in zip(dev.residual_after, host.residual_after,
                          strict=True):
        assert np.array_equal(rg_d, rg_h)
    for r, ((dr, db), (hr, hb)) in enumerate(
            zip(dev.rounds_log, host.rounds_log, strict=True)):
        assert np.array_equal(dr, hr), f"rho_eff differs at round {r}"
        assert np.array_equal(db, hb), f"masks differ at round {r}"
    if dev.admission_log is not None or host.admission_log is not None:
        for r, (dd, hd) in enumerate(zip(dev.admission_log,
                                         host.admission_log, strict=True)):
            assert np.array_equal(dd, hd), f"drops differ at round {r}"


# -- engine-level differential suite ------------------------------------------

@pytest.mark.parametrize("config", ["plain", "rho_weighted", "avail",
                                    "priced", "tight"])
def test_admission_device_bit_identical_to_host_ledger(config):
    t, loads = _fleet(T=12)
    kw = dict(residual=np.full(t.n, 3, np.int64))
    if config == "rho_weighted":
        kw["rho_weighted"] = True
    elif config == "avail":
        av = np.ones(t.n, bool)
        av[5:9] = False
        kw["avail"] = [av if i % 2 else None for i in range(len(loads))]
    elif config == "priced":
        # pricing steers, the ledger enforces — both at once
        kw.update(capacity=np.full(t.n, 3.0), cap_beta=1.5, cap_frac=0.5)
    elif config == "tight":
        kw["residual"] = np.full(t.n, 1, np.int64)   # heavy truncation
    dev = solve_congestion(t, loads, 4, record_rounds=True,
                           device_loop=True, **kw)
    host = solve_congestion(t, loads, 4, record_rounds=True,
                            device_loop=False, **kw)
    _assert_bit_identical(dev, host)


@pytest.mark.parametrize("device_loop", [True, False])
def test_admission_placements_feasible_wholesale(device_loop):
    """The returned wave never overdraws any switch — that is the whole
    point of moving admission inside the loop — and the reported ledger
    deltas are exact."""
    t, loads = _fleet(T=12)
    residual = np.full(t.n, 2, np.int64)
    res = solve_congestion(t, loads, 4, residual=residual,
                           device_loop=device_loop)
    claims = res.blue.sum(axis=0).astype(np.int64)
    assert (claims <= residual).all()
    after, = res.residual_after
    assert np.array_equal(after, residual - claims)
    assert (after >= 0).all()
    # dropped counts are per-tenant claims the ledger refused
    assert res.admission_dropped.shape == (len(loads),)
    assert (res.admission_dropped >= 0).all()


def test_admission_zero_residual_switches_are_hard_unavailable():
    t, loads = _fleet(T=4)
    residual = np.full(t.n, 2, np.int64)
    residual[3:10] = 0
    for device_loop in (True, False):
        res = solve_congestion(t, loads, 4, residual=residual,
                               device_loop=device_loop)
        assert not res.blue[:, 3:10].any()


def test_admission_fleet_per_tree_ledgers_bit_identical():
    fleet = build_fleet(2, 2, 2, 4)
    trees = [tp.tree for tp in fleet.topos]
    tree_of = [0, 0, 0, 1, 1, 1]
    loads = [sample_load(trees[g], "power-law", seed=7 + i)
             for i, g in enumerate(tree_of)]
    residual = [np.full(tr.n, 2, np.int64) for tr in trees]
    kw = dict(core_rho=fleet.core_rho, core_path=fleet.core_path,
              residual=residual, record_rounds=True)
    dev = solve_fleet(trees, loads, tree_of, 3, device_loop=True, **kw)
    host = solve_fleet(trees, loads, tree_of, 3, device_loop=False, **kw)
    _assert_bit_identical(dev, host)
    for g, tr in enumerate(trees):
        rows = [i for i, gg in enumerate(tree_of) if gg == g]
        claims = dev.blue[rows, : tr.n].sum(axis=0).astype(np.int64)
        assert (claims <= residual[g]).all()
        assert np.array_equal(dev.residual_after[g], residual[g] - claims)


# -- boundary validation (engine + planner) -----------------------------------

def test_solve_boundary_rejects_malformed_knobs():
    t, loads = _fleet(n=16, T=2)
    good = np.full(t.n, 2, np.int64)
    for bad_frac in (0.0, 1.5, -0.25, float("nan")):
        with pytest.raises(ValueError, match="cap_frac"):
            solve_congestion(t, loads, 2, capacity=np.full(t.n, 2.0),
                             cap_frac=bad_frac)
    for bad_beta in (-1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="cap_beta"):
            solve_congestion(t, loads, 2, capacity=np.full(t.n, 2.0),
                             cap_beta=bad_beta)
    with pytest.raises(ValueError, match="non-negative"):
        cap = np.full(t.n, 2.0)
        cap[0] = -1.0
        solve_congestion(t, loads, 2, capacity=cap)
    with pytest.raises(ValueError, match="residual shape"):
        solve_congestion(t, loads, 2, residual=good[:-1])
    with pytest.raises(ValueError, match="integer-valued"):
        solve_congestion(t, loads, 2, residual=np.full(t.n, 1.5))
    with pytest.raises(ValueError, match="non-negative"):
        bad = good.copy()
        bad[2] = -1
        solve_congestion(t, loads, 2, residual=bad)


def test_plan_congestion_residual_boundary():
    topo = fleet_tree(2, 4, 4)
    n = topo.tree.n
    with pytest.raises(ValueError, match="plan_congestion: residual shape"):
        plan_congestion(topo, 3, count=2, residual=np.ones(n - 1, np.int64))
    with pytest.raises(ValueError, match="integer-valued"):
        plan_congestion(topo, 3, count=2, residual=np.full(n, 0.5))
    with pytest.raises(ValueError, match="non-negative"):
        bad = np.full(n, 2, np.int64)
        bad[0] = -2
        plan_congestion(topo, 3, count=2, residual=bad)
    cp = plan_congestion(topo, 3, count=2, residual=np.full(n, 2))
    claims = np.zeros(n, np.int64)
    for p in cp.plans:
        claims += p.blue
    assert (claims <= 2).all()


# -- orchestrator: one-solve admission, preemption, telemetry -----------------

def _orch(k=4, capacity=2):
    topo = fleet_tree(2, 4, 4)
    return Orchestrator(topo, OrchestratorConfig(k=k, capacity=capacity))


def test_device_admission_one_solve_where_host_path_collides():
    """The acceptance scenario: a T=16 wave on a capacity-2 fleet. The
    host path admits serially and pays a re-solve per collision; the
    device path gets the whole feasible wave from ONE solve — >= 2x
    fewer host<->device admission round trips and zero evictions."""
    host = _orch()
    host.begin_workloads(16, congestion_aware=True, max_rounds=2)
    h = host.last_admission
    assert h["path"] == "host" and h["collisions"] >= 1
    assert h["round_trips"] == 1 + h["collisions"]

    dev = _orch()
    progs = dev.begin_workloads(16, congestion_aware=True,
                                device_admission=True, max_rounds=2)
    d = dev.last_admission
    assert len(progs) == 16
    assert d["path"] == "device"
    assert d["solves"] == 1 and d["round_trips"] == 1
    assert d["collisions"] == 0 and d["preempted"] == ()
    assert h["round_trips"] >= 2 * d["round_trips"]
    assert (dev._residual >= 0).all()
    # ledger conservation: residual + own blue + registered claims == cap
    claims = dev.blue.astype(np.int64).copy()
    for j in dev.jobs.values():
        claims += j.blue.astype(np.int64)
    assert np.array_equal(dev._residual + claims,
                          np.full(dev.topo.tree.n, 2, np.int64))


def test_device_admission_matches_engine_ledger_reference():
    """The orchestrator's admitted masks ARE the engine's: replaying the
    same residual ledger through solve_congestion (host reference path)
    reproduces them bit for bit."""
    orch = _orch()
    residual = orch._residual.copy()
    avail = orch._avail()
    orch.begin_workloads(6, congestion_aware=True, device_admission=True,
                         max_rounds=2)
    ref = solve_congestion(orch.topo.tree, [orch.topo.load] * 6, orch.cfg.k,
                           avail=[avail] * 6, residual=residual,
                           device_loop=False, max_rounds=2)
    admitted = np.stack([j.blue for j in
                         sorted(orch.jobs.values(),
                                key=lambda j: j.order)])
    assert np.array_equal(admitted, ref.blue)


def test_preemption_policies_order_victims():
    lo = dict(tree=0, blue=np.zeros(1, bool), utilization=0.0)
    from repro.runtime import JobRecord
    jobs = [JobRecord(job_id=1, priority=2, order=1, benefit=5.0, **lo),
            JobRecord(job_id=2, priority=0, order=2, benefit=1.0, **lo),
            JobRecord(job_id=3, priority=1, order=3, benefit=9.0, **lo)]
    assert [j.job_id for j in
            PreemptionPolicy("priority").order_victims(jobs)] == [2, 3, 1]
    assert [j.job_id for j in
            PreemptionPolicy("youngest-first").order_victims(jobs)] \
        == [3, 2, 1]
    assert [j.job_id for j in
            PreemptionPolicy("cheapest-regression").order_victims(jobs)] \
        == [2, 1, 3]
    with pytest.raises(ValueError):
        PreemptionPolicy("oldest")
    with pytest.raises(ValueError):
        PreemptionPolicy("priority", max_victims=0)


def test_preemptive_admission_evicts_then_fits():
    orch = _orch()
    # leave the ledger scarce-but-nonzero (an exhausted switch is simply
    # unavailable; preemption engages on in-loop truncation), then admit
    # a wave the remaining capacity cannot cover
    for _ in range(3):
        orch.begin_workload(priority=1)
    before_jobs = set(orch.jobs)
    progs = orch.begin_workloads(
        8, congestion_aware=True, device_admission=True,
        preemption=PreemptionPolicy("priority"), priority=0, max_rounds=2)
    a = orch.last_admission
    assert len(progs) == 8
    assert a["solves"] == 2 and tuple(a["preempted"])
    assert set(a["preempted"]) <= before_jobs
    assert orch.preemption_events[-1]["policy"] == "priority"
    assert orch.preemption_events[-1]["freed"] > 0
    assert (orch._residual >= 0).all()
    # evicted jobs left the registry; their claims returned to the ledger
    claims = orch.blue.astype(np.int64).copy()
    for j in orch.jobs.values():
        claims += j.blue.astype(np.int64)
    assert np.array_equal(orch._residual + claims,
                          np.full(orch.topo.tree.n, 2, np.int64))


def test_release_workloads_frees_ledger_exactly():
    orch = _orch()
    orch.begin_workloads(4, congestion_aware=True, device_admission=True,
                         max_rounds=2)
    ids = sorted(orch.jobs)
    res0 = orch._residual.copy()
    held = sum(int(orch.jobs[i].blue.sum()) for i in ids[:2])
    freed = orch.release_workloads(ids[:2])
    assert freed == held
    assert int((orch._residual - res0).sum()) == freed
    with pytest.raises(KeyError):
        orch.release_workloads([ids[0]])          # already released


def test_admission_cache_serves_identical_wave():
    a, b = _orch(), _orch()
    a.begin_workloads(4, congestion_aware=True, device_admission=True)
    blues_a = [j.blue.copy() for j in sorted(a.jobs.values(),
                                             key=lambda j: j.order)]
    # same orchestrator state recurs -> cache hit, zero solves
    b.begin_workloads(4, congestion_aware=True, device_admission=True)
    b.release_workloads(sorted(b.jobs))
    b.begin_workloads(4, congestion_aware=True, device_admission=True)
    t = b.last_admission
    assert t["cache_hit"] and t["solves"] == 0 and t["round_trips"] == 0
    blues_b = [j.blue.copy() for j in sorted(b.jobs.values(),
                                             key=lambda j: j.order)]
    for x, y in zip(blues_a, blues_b, strict=True):
        assert np.array_equal(x, y)


def test_device_admission_guardrails():
    orch = _orch()
    with pytest.raises(ValueError, match="congestion_aware"):
        orch.begin_workloads(2, device_admission=True)
    with pytest.raises(ValueError, match="device_admission"):
        orch.begin_workloads(2, congestion_aware=True,
                             preemption=PreemptionPolicy())
    with pytest.raises(ValueError, match="residual"):
        orch.begin_workloads(2, congestion_aware=True, device_admission=True,
                             residual=np.ones(orch.topo.tree.n, np.int64))


def test_fleet_device_admission_per_tree():
    fleet = build_fleet(2, 2, 2, 4)
    orch = Orchestrator(fleet, OrchestratorConfig(k=3, capacity=2))
    progs = orch.begin_workloads(fleet=[3, 3], congestion_aware=True,
                                 device_admission=True, max_rounds=2)
    assert len(progs) == 6
    a = orch.last_admission
    assert a["path"] == "device" and a["collisions"] == 0
    assert a["solves"] == 1
    for g, res_g in enumerate(orch._residuals):
        assert (res_g >= 0).all()
        claims = np.zeros(res_g.shape[0], np.int64)
        for j in orch.jobs.values():
            if j.tree == g:
                claims += j.blue.astype(np.int64)
        if g == 0:
            claims += orch.blue.astype(np.int64)
        assert np.array_equal(res_g + claims,
                              np.full(res_g.shape[0], 2, np.int64))
