"""Checkpoint layer: atomic commits, retention, bf16, exact resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import api
from repro.optim import adamw


def _tree(seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "w": jax.random.normal(k[0], (4, 8)),
        "nested": {"b": jax.random.normal(k[1], (8,)).astype(jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact_including_bf16(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t)
    got, step = ckpt.restore(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_retention(tmp_path):
    t = _tree()
    mgr = ckpt.CheckpointManager(tmp_path, keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert ckpt.latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_async_save_waits(tmp_path):
    mgr = ckpt.CheckpointManager(tmp_path, keep_n=3, async_save=True)
    mgr.save(11, _tree())
    mgr.wait()
    assert ckpt.latest_step(tmp_path) == 11


def test_no_partial_checkpoint_on_disk(tmp_path):
    ckpt.save(tmp_path, 5, _tree())
    names = [p.name for p in tmp_path.iterdir()]
    assert not any(n.startswith(".tmp_") for n in names)
    # manifest + arrays both present (atomic rename of a complete dir)
    d = tmp_path / "step_00000005"
    assert (d / "manifest.json").exists() and (d / "arrays.npz").exists()


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"w": jnp.zeros((5,))})


def test_exact_training_resume(tmp_path):
    """Crash/resume == uninterrupted run (deterministic pipeline + ckpt)."""
    cfg = ARCHS["granite-20b"].reduced()
    ocfg = adamw.AdamWConfig()
    data = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=32, seed=3))
    lfn = api.loss_fn(cfg)

    @jax.jit
    def step(params, opt, batch):
        grads = jax.grad(lambda p: lfn(p, batch)[0])(params)
        params, opt, _ = adamw.update(grads, opt, params, ocfg)
        return params, opt

    def run(n_steps, start=0, params=None, opt=None):
        if params is None:
            params = api.init_fn(cfg)(jax.random.PRNGKey(0))
            opt = adamw.init(params, ocfg)
        for s in range(start, n_steps):
            params, opt = step(params, opt, data.batch(s))
        return params, opt

    # uninterrupted 6 steps
    p_full, o_full = run(6)
    # interrupted at 3 + checkpoint + resume
    p3, o3 = run(3)
    ckpt.save(tmp_path, 3, {"params": p3, "opt": o3})
    state, start = ckpt.restore(tmp_path, {"params": p3, "opt": o3})
    p_res, o_res = run(6, start=start, params=state["params"],
                       opt=state["opt"])
    for a, b in zip(jax.tree.leaves(p_res), jax.tree.leaves(p_full)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)
