"""Fault domains, degraded-mode aggregation, preplan cache, chaos harness."""
import numpy as np
import pytest

from repro.collectives import (degrade_links, fail_devices, fail_switches,
                               fleet_tree)
from repro.collectives.schedule import build_program, plan
from repro.core.reduce import all_red, phi
from repro.core.soar import soar
from repro.runtime import (ChaosHarness, FaultEvent, InvariantViolation,
                           Orchestrator, OrchestratorConfig,
                           generate_scenario)
from repro.runtime.faults import _storm_limit
from repro.testing import given, settings, st


def mk(k=3, capacity=None, **kw):
    topo = fleet_tree(n_pods=2, racks_per_pod=4, chips_per_rack=4)
    return topo, Orchestrator(topo, OrchestratorConfig(k=k, capacity=capacity,
                                                       **kw))


# -- topology-level fault domains ---------------------------------------------

def test_fail_switches_blocks_candidates():
    topo = fleet_tree(2, 2, 4)
    t2 = fail_switches(topo, [1, 4])
    assert t2.blocked[1] and t2.blocked[4] and t2.blocked.sum() == 2
    # tree and loads untouched: the switch still forwards
    assert np.array_equal(t2.load, topo.load)
    assert np.array_equal(t2.tree.rho, topo.tree.rho)
    cand = t2.candidates()
    assert not cand[1] and not cand[4] and cand.sum() == t2.tree.n - 2
    # intersection with an extra avail mask
    extra = np.ones(t2.tree.n, bool)
    extra[2] = False
    both = t2.candidates(extra)
    assert not both[1] and not both[2] and not both[4]
    with pytest.raises(ValueError):
        fail_switches(t2, [1])                # already failed
    with pytest.raises(ValueError):
        fail_switches(topo, [topo.tree.n])    # out of range


def test_fail_switches_isolate_drains_subtree():
    topo = fleet_tree(2, 2, 4)
    pod = 1                                    # first pod switch
    t2 = fail_switches(topo, [pod], isolate=True)
    # every device under the pod is disconnected -> its load drained
    sub = [v for v in range(topo.tree.n)
           if v == pod or topo.tree.parent[v] == pod]
    assert all(t2.load[v] == 0 for v in sub)
    gone = [d for d, leaf in enumerate(topo.device_leaf)
            if topo.tree.parent[leaf] == pod]
    assert all(t2.device_leaf[d] == -1 for d in gone)
    assert t2.load.sum() == topo.load.sum() - len(gone)
    assert t2.blocked[pod]


def test_degrade_links_scales_rho():
    topo = fleet_tree(2, 2, 4)
    t2 = degrade_links(topo, {3: 0.5, 4: 0.25})
    assert t2.tree.rho[3] == pytest.approx(topo.tree.rho[3] * 2)
    assert t2.tree.rho[4] == pytest.approx(topo.tree.rho[4] * 4)
    untouched = [v for v in range(topo.tree.n) if v not in (3, 4)]
    assert np.array_equal(t2.tree.rho[untouched], topo.tree.rho[untouched])
    for bad in ({-1: 0.5}, {topo.tree.n: 0.5}, {0: 0.0}, {0: -1.0},
                {0: float("nan")}):
        with pytest.raises(ValueError):
            degrade_links(topo, bad)


def test_fail_devices_preserves_blocked():
    topo = fail_switches(fleet_tree(2, 2, 4), [2])
    t2 = fail_devices(topo, [0, 1])
    assert t2.blocked is not None and t2.blocked[2]


def test_build_program_rejects_blue_on_blocked():
    topo = fail_switches(fleet_tree(2, 2, 4), [1])
    blue = np.zeros(topo.tree.n, bool)
    blue[1] = True
    with pytest.raises(ValueError, match="failed switch"):
        build_program(topo, blue)


def test_plan_respects_blocked_switches():
    topo = fleet_tree(2, 2, 4)
    blue0, _ = plan(topo, 3)
    hit = int(np.nonzero(blue0)[0][0])
    blue, prog = plan(fail_switches(topo, [hit]), 3)
    assert not blue[hit]
    # matches the serial solver under the same candidate mask
    avail = np.ones(topo.tree.n, bool)
    avail[hit] = False
    assert prog.utilization == pytest.approx(
        soar(topo.tree, topo.load, 3, avail=avail).cost)


# -- orchestrator: switch failures, degraded mode, preplan cache --------------

def test_switch_failure_degraded_then_replan():
    topo, orch = mk(k=3)
    u0 = orch.program.utilization
    hit = int(np.nonzero(orch.blue)[0][0])
    orch.on_switch_failure([hit])
    ev = orch.degraded_events[-1]
    assert ev["switches"] == (hit,) and ev["was_blue"] == (hit,)
    # degraded mode: losing one aggregator regresses utilization, but is
    # bounded by all-red, and the replanned placement recovers some of it
    assert u0 < ev["degraded_utilization"]
    assert ev["degraded_utilization"] <= phi(
        orch.topo.tree, orch.topo.load,
        np.zeros(orch.topo.tree.n, bool))
    assert ev["utilization"] <= ev["degraded_utilization"]
    assert not orch.blue[hit]
    # failing a non-blue switch has no degraded-mode step
    cold = int(np.nonzero(~orch.blue & ~orch.switch_blocked)[0][0])
    orch.on_switch_failure([cold])
    assert orch.degraded_events[-1]["degraded_utilization"] is None
    # validation: double-fail and range
    with pytest.raises(ValueError):
        orch.on_switch_failure([hit])
    with pytest.raises(ValueError):
        orch.on_switch_failure([orch.topo0.tree.n])
    # recovery restores the original utilization
    orch.on_switch_recover([hit, cold])
    assert orch.program.utilization == pytest.approx(u0)
    with pytest.raises(ValueError):
        orch.on_switch_recover([hit])          # not failed any more


def test_preplan_switch_failures_cache_hit_bit_identical():
    """The ISSUE's regression: a preplan-cache hit must return a placement
    bit-identical to what a fresh engine solve of the scenario produces."""
    topo, orch = mk(k=3, capacity=2)
    planned = orch.preplan_switch_failures()
    n_open = int((~orch.switch_blocked).sum())
    assert len(planned) == n_open
    replans0 = orch.replans
    for s in np.nonzero(~orch.switch_blocked)[0][:4]:
        s = int(s)
        orch.on_switch_failure([s])
        assert orch.degraded_events[-1]["cache_hit"]
        fresh_blue, fresh_prog = plan(orch.topo, orch.cfg.k,
                                      avail=orch._replan_avail(),
                                      strategy=orch.cfg.strategy)
        assert np.array_equal(orch.blue, fresh_blue)
        assert orch.program.utilization == fresh_prog.utilization
        orch.on_switch_recover([s])            # back to a memoized state
    assert orch.replans == replans0            # zero engine solves in loop
    stats = orch.preplan_cache_stats()
    assert stats["hits"] == 8 and stats["cache_recoveries"] == 8


def test_preplan_cache_staleness_evicts():
    """Entries solved under a different capacity landscape are stale: they
    must be evicted and recovered around with a fresh solve, not served."""
    topo, orch = mk(k=3, capacity=1)
    orch.preplan_switch_failures([[0]])
    orch.begin_workload()                      # capacity landscape shifts
    orch.on_switch_failure([0])
    stats = orch.preplan_cache_stats()
    assert stats["stale"] == 1 and stats["hits"] == 0
    assert not orch.degraded_events[-1]["cache_hit"]
    # the fresh solve respected the shifted capacity
    assert (orch._residual >= 0).all()


def test_device_failure_recovery_is_cached():
    topo, orch = mk(k=3)
    orch.preplan_failures([[0], [1]])
    replans0 = orch.replans
    orch.on_failure([0])                       # preplanned -> hit
    orch.on_recover([0])                       # initial state memoized -> hit
    assert orch.replans == replans0
    assert orch.preplan_cache_stats()["hits"] == 2
    orch.on_failure([5])                       # never preplanned -> miss
    assert orch.replans == replans0 + 1


def test_link_degrade_replans_with_updated_rho():
    topo, orch = mk(k=3)
    u0 = orch.program.utilization
    spine_kids = [v for v in range(topo.tree.n) if topo.tree.parent[v] == 0]
    v = spine_kids[0]
    orch.on_link_degrade({v: 0.5})             # pod uplink at half rate
    degraded = degrade_links(topo, {v: 0.5})
    assert orch.program.utilization == pytest.approx(
        soar(degraded.tree, degraded.load, 3).cost)
    assert orch.program.utilization >= u0
    with pytest.raises(ValueError):
        orch.on_link_degrade({v: 0.0})
    # restoring the rate lands back on the memoized initial placement
    replans0 = orch.replans
    orch.on_link_degrade({v: 1.0})
    assert orch.program.utilization == pytest.approx(u0)
    assert orch.replans == replans0


def test_engine_cache_stats_includes_preplan():
    topo, orch = mk(k=2)
    stats = orch.engine_cache_stats()
    assert "preplan" in stats
    assert stats["preplan"] == orch.preplan_cache_stats()
    assert {"hits", "misses", "stale", "entries",
            "cache_recoveries"} <= set(stats["preplan"])


# -- chaos harness ------------------------------------------------------------

def test_generate_scenario_deterministic_and_feasible():
    topo = fleet_tree(2, 2, 4)
    cfg = OrchestratorConfig(k=3, straggler_quantile=0.5)
    a = generate_scenario(topo, n_events=40, seed=11, cfg=cfg)
    b = generate_scenario(topo, n_events=40, seed=11, cfg=cfg)
    assert a == b and len(a) == 40
    c = generate_scenario(topo, n_events=40, seed=12, cfg=cfg)
    assert a != c                              # seed actually matters
    # mirror feasibility: replay the bookkeeping and check bounds
    failed, quarantined, blocked = set(), set(), set()
    min_healthy = max(2, topo.n_devices // 4)
    for ev in a:
        if ev.kind == "fail_device":
            assert not (set(ev.devices) & (failed | quarantined))
            failed |= set(ev.devices)
        elif ev.kind == "recover_device":
            assert set(ev.devices) <= failed
            failed -= set(ev.devices)
        elif ev.kind == "fail_switch":
            assert not (set(ev.switches) & blocked)
            blocked |= set(ev.switches)
        elif ev.kind == "recover_switch":
            assert set(ev.switches) <= blocked
            blocked -= set(ev.switches)
        elif ev.kind == "straggler_storm":
            alive = topo.n_devices - len(failed) - len(quarantined)
            assert 1 <= len(ev.devices) <= _storm_limit(
                alive, cfg.straggler_quantile)
            assert ev.steps == cfg.straggler_patience
            quarantined |= set(ev.devices)
        elif ev.kind == "recover_quarantined":
            quarantined = set()
        elif ev.kind == "fail_rack":
            assert not (set(ev.switches) & blocked)
            failed |= set(ev.devices)
            blocked |= set(ev.switches)
        assert topo.n_devices - len(failed) - len(quarantined) >= min_healthy
        assert len(blocked) <= topo.tree.n // 2


def test_storm_quarantines_exactly_the_slow_set():
    topo, orch = mk(k=3)                       # 32 devices, q=0.9 -> cap 3
    ev = FaultEvent("straggler_storm", devices=(4, 9, 17),
                    steps=orch.cfg.straggler_patience, slow=8.0)
    ChaosHarness(orch).step(ev)
    assert set(np.nonzero(orch.quarantined)[0]) == {4, 9, 17}
    assert orch.n_alive == topo.n_devices - 3
    # recover_quarantined drains them; a second one is a clean no-op
    h = ChaosHarness(orch)
    h.step(FaultEvent("recover_quarantined"))
    assert orch.n_alive == topo.n_devices
    h.step(FaultEvent("recover_quarantined"))


def test_chaos_harness_detects_violations():
    topo, orch = mk(k=3)
    h = ChaosHarness(orch)
    h.check_invariants()                       # healthy state passes
    orch.program = build_program(
        orch.topo, np.zeros(orch.topo.tree.n, bool))   # stale program
    with pytest.raises(InvariantViolation, match="utilization"):
        h.check_invariants()


def test_chaos_scenario_50_events_all_invariants():
    """The acceptance scenario: >= 50 mixed seeded events, every invariant
    checked after each one, cache-served recoveries verified against fresh
    solves (the harness raises InvariantViolation otherwise)."""
    topo = fleet_tree(2, 2, 4)
    cfg = OrchestratorConfig(k=3, capacity=2, straggler_quantile=0.5)
    events = generate_scenario(topo, n_events=50, seed=7, cfg=cfg)
    kinds = {e.kind for e in events}
    assert len(kinds) >= 5                     # genuinely mixed
    orch = Orchestrator(topo, cfg)
    orch.preplan_switch_failures()
    report = ChaosHarness(orch, verify_cache_hits=True).run(events)
    assert report.events == 50
    assert report.invariant_checks == 50
    # every event recovers via cache or solve, except the two kinds that
    # legitimately don't replace the placement: no-op recover_quarantined
    # and preplan_links (cache fills for later degrades)
    assert report.cache_hits + report.replans >= 50 - sum(
        e.kind in ("recover_quarantined", "preplan_links") for e in events)
    assert (orch._residual >= 0).all()


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_chaos_invariants_hold_for_random_seeds(seed):
    """Property: any feasible event sequence keeps every invariant. The
    harness raises InvariantViolation on the first violated check."""
    topo = fleet_tree(2, 2, 2)
    cfg = OrchestratorConfig(k=2, capacity=2, straggler_quantile=0.5,
                             straggler_patience=2)
    events = generate_scenario(topo, n_events=12, seed=seed, cfg=cfg,
                               admits=True)
    orch = Orchestrator(topo, cfg)
    report = ChaosHarness(orch, verify_cache_hits=True).run(events)
    assert report.invariant_checks == 12


# -- partial-capacity degrades + training-coupled chaos (PR 9) ----------------

def test_generate_scenario_emits_capacity_degrades():
    topo = fleet_tree(2, 2, 4)
    cfg = OrchestratorConfig(k=3, straggler_quantile=0.5)
    events = generate_scenario(topo, n_events=50, seed=21, cfg=cfg)
    kinds = {e.kind for e in events}
    assert "degrade_switch" in kinds
    # mirror feasibility: never degrade an already-degraded or blocked
    # plane, recover only degraded ones, fractions from CAP_FRACS
    from repro.runtime.faults import CAP_FRACS
    cap_degraded, blocked = set(), set()
    for ev in events:
        if ev.kind == "degrade_switch":
            (s, f), = ev.rates
            assert s not in cap_degraded and s not in blocked
            assert f in CAP_FRACS
            cap_degraded.add(s)
        elif ev.kind == "recover_switch_capacity":
            (s, f), = ev.rates
            assert s in cap_degraded and f == 1.0
            cap_degraded.discard(s)
        elif ev.kind == "fail_switch":
            blocked |= set(ev.switches)
        elif ev.kind == "recover_switch":
            blocked -= set(ev.switches)
        elif ev.kind == "fail_rack":
            blocked |= set(ev.switches)
    # crash events only appear for training-coupled scenarios
    assert "crash" not in kinds
    trained = generate_scenario(topo, n_events=200, seed=21, cfg=cfg,
                                train=True)
    assert any(e.kind == "crash" for e in trained)


def test_chaos_scenario_with_degrades_all_invariants():
    """50 seeded events including partial-capacity degrade events, with
    the capacity ledger on: zero invariant violations (the harness raises
    otherwise), and the ledger balances through evictions."""
    topo = fleet_tree(2, 2, 4)
    cfg = OrchestratorConfig(k=3, capacity=2, straggler_quantile=0.5)
    events = generate_scenario(topo, n_events=50, seed=21, cfg=cfg,
                               admits=True)
    assert sum(e.kind == "degrade_switch" for e in events) >= 2
    orch = Orchestrator(topo, cfg)
    report = ChaosHarness(orch, verify_cache_hits=True).run(events)
    assert report.events == 50
    assert report.invariant_checks == 50
    assert (orch._residual >= 0).all()


def test_chaos_over_fleet_topology():
    """Chaos over a multi-tree Fleet: the orchestrator's own tree takes
    the events (incl. preplan_links replay) while the fleet's shared-core
    pricing stays in every fingerprint."""
    from repro.collectives import build_fleet
    fleet = build_fleet(2, 2, 2, 2)
    cfg = OrchestratorConfig(k=2, capacity=2, straggler_quantile=0.5,
                             straggler_patience=2)
    orch = Orchestrator(fleet, cfg)
    events = generate_scenario(fleet.topos[0], n_events=40, seed=5,
                               cfg=cfg, admits=True)
    report = ChaosHarness(orch, verify_cache_hits=True).run(events)
    assert report.invariant_checks == 40
    # the preplan_links -> degrade_link replay path fills and serves the
    # cache (mirror recoveries also hit); a fleet run still gets lookups
    preplans = sum(e.kind == "preplan_links" for e in events)
    if preplans and report.cache_hits == 0:
        # at minimum the entries exist for the preplanned what-ifs
        assert orch.preplan_cache_stats()["entries"] > 0


def test_training_coupled_chaos_single_device(tmp_path):
    """ChaosTrainer on the in-process device: every event drives a real
    optimizer step, lossless events are bitwise-checked against the
    fault-free program, crashes restart from the checkpoint."""
    jax = pytest.importorskip("jax")
    from repro.launch.train import dp_fleet
    from repro.runtime import ChaosTrainer

    topo = dp_fleet(jax.device_count())
    cfg = OrchestratorConfig(k=min(2, topo.tree.n))
    orch = Orchestrator(topo, cfg)
    blues = np.nonzero(orch.blue)[0]
    s = int(blues[0]) if len(blues) else 0     # 1-device fleets go all-red
    trainer = ChaosTrainer(orch, seq=16, global_batch=4,
                           ckpt_dir=str(tmp_path), ckpt_every=2)
    h = ChaosHarness(orch, trainer=trainer)
    events = [
        FaultEvent("degrade_switch", rates=((s, 0.5),)),
        FaultEvent("degrade_switch", rates=((s, 0.25),)),
        FaultEvent("crash"),
        FaultEvent("recover_switch_capacity", rates=((s, 1.0),)),
        FaultEvent("crash"),
    ]
    report = h.run(events)
    tr = report.train
    assert tr["steps"] == len(events)
    assert tr["restores"] == 2
    assert tr["bitwise_checks"] >= 1
    assert report.invariant_checks == len(events)
    losses = [r["loss"] for r in report.records]
    assert all(np.isfinite(losses))
    # crash without a checkpoint directory is an invariant violation
    t2 = ChaosTrainer(Orchestrator(topo, cfg), seq=16, global_batch=4)
    with pytest.raises(InvariantViolation, match="checkpoint"):
        ChaosHarness(t2.orch, trainer=t2).step(FaultEvent("crash"))


# -- admission / preemption claim-ledger fuzz (PR 10) -------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_claim_ledger_conservation_under_admission_interleavings(seed):
    """Property: across random interleavings of device-admission waves,
    preemptive admissions, job releases, switch failures, partial
    capacity degrades, and recoveries, every tree's residual plus its
    registered claims reconstructs the effective per-switch capacity and
    the residual never goes negative. The harness's per-step
    ``check_invariants`` raises on the first violation."""
    from repro.runtime import PreemptionPolicy
    topo = fleet_tree(2, 2, 2)
    cfg = OrchestratorConfig(k=2, capacity=2, straggler_quantile=0.5)
    orch = Orchestrator(topo, cfg)
    h = ChaosHarness(orch, verify_cache_hits=False)
    rng = np.random.default_rng(seed)
    n = topo.tree.n
    blocked: set[int] = set()
    degraded: set[int] = set()
    for _ in range(12):
        ops = ["admit", "preempt", "release"]
        if len(blocked) + 1 <= n // 2:
            ops.append("fail_switch")
        if blocked:
            ops.append("recover_switch")
        free = [v for v in range(n)
                if v not in degraded and v not in blocked]
        if free:
            ops.append("degrade_switch")
        if degraded:
            ops.append("recover_capacity")
        op = str(rng.choice(ops))
        if op == "admit":
            ev = FaultEvent("admit_jobs", count=int(rng.integers(1, 3)))
        elif op == "preempt":
            ev = FaultEvent("preempt_admit", count=int(rng.integers(1, 3)),
                            policy=str(rng.choice(PreemptionPolicy.KINDS)))
        elif op == "release":
            ev = FaultEvent("release_jobs", count=int(rng.integers(1, 3)))
        elif op == "fail_switch":
            s = int(rng.choice([v for v in range(n) if v not in blocked]))
            blocked.add(s)
            ev = FaultEvent("fail_switch", switches=(s,))
        elif op == "recover_switch":
            s = int(rng.choice(sorted(blocked)))
            blocked.discard(s)
            ev = FaultEvent("recover_switch", switches=(s,))
        elif op == "degrade_switch":
            s = int(rng.choice(free))
            degraded.add(s)
            ev = FaultEvent("degrade_switch", rates=((s, 0.5),))
        else:
            s = int(rng.choice(sorted(degraded)))
            degraded.discard(s)
            ev = FaultEvent("recover_switch_capacity", rates=((s, 1.0),))
        h.step(ev)
        assert (orch._residual >= 0).all()
    assert h.invariant_checks == 12


@pytest.mark.slow
def test_degraded_executor_and_training_subprocess():
    """8-device shard_map: degraded programs bitwise-identical to the
    fault-free reduce, and the training-coupled chaos loop end-to-end."""
    import pathlib
    import subprocess
    import sys
    script = (pathlib.Path(__file__).parent / "helpers"
              / "degraded_check.py")
    import os
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run([sys.executable, str(script)],
                         cwd=str(pathlib.Path(__file__).parent.parent),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "DEGRADED_CHECK_OK" in out.stdout
