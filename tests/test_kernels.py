"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.minplus.ops import minplus
from repro.kernels.minplus.ref import minplus_ref
from repro.kernels.segment_reduce.ops import segment_reduce
from repro.kernels.segment_reduce.ref import segment_reduce_ref
from repro.kernels.topk_compress.ops import decompress, topk_compress
from repro.kernels.topk_compress.ref import topk_compress_ref
from repro.core.soar import minplus as minplus_numpy


# ---------------------------------------------------------------------------
# minplus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,k", [(1, 4), (7, 33), (64, 128), (130, 17)])
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_minplus_shapes(rows, k, dtype):
    rng = np.random.default_rng(rows * 1000 + k)
    a = rng.uniform(0, 50, (rows, k)).astype(dtype)
    b = rng.uniform(0, 50, (rows, k)).astype(dtype)
    got = np.asarray(minplus(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(minplus_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_minplus_with_infs_matches_soar_reference():
    """Oracle chain: pallas == jnp ref == the numpy DP helper in core.soar."""
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 9, (5, 12))
    b = rng.uniform(0, 9, (5, 12))
    a[:, 7:] = np.inf  # capped / infeasible budget entries
    want = minplus_numpy(a, b, out_w=12)  # numpy reference from the DP
    got = np.asarray(minplus(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("rows,k", [(1, 1), (9, 7), (70, 33)])
def test_minplus_engine_fused_path_matches_ref(rows, k):
    """The engine's fused jnp shift-reduction == the quadratic jnp oracle
    (including BIG-sentinel entries, the engine's finite stand-in for inf)."""
    from repro.engine.batched import BIG, _minplus_fused
    rng = np.random.default_rng(rows * 13 + k)
    a = rng.uniform(0, 50, (rows, k)).astype(np.float32)
    b = rng.uniform(0, 50, (rows, k)).astype(np.float32)
    a[rng.random((rows, k)) < 0.2] = BIG
    b[rng.random((rows, k)) < 0.2] = BIG
    got = np.asarray(_minplus_fused(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(minplus_ref(jnp.asarray(a), jnp.asarray(b)))
    # entries involving BIG are saturated garbage by design; compare the
    # real-valued region exactly and the rest only for finiteness
    realish = want < BIG
    np.testing.assert_allclose(got[realish], want[realish], rtol=1e-6)
    assert np.isfinite(got).all()
    assert (got[~realish] >= BIG * 0.999).all()


# ---------------------------------------------------------------------------
# segment_reduce
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,c,d", [(1, 1, 8), (4, 7, 130), (16, 32, 512),
                                   (3, 5, 1000)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_segment_reduce(g, c, d, dtype):
    rng = np.random.default_rng(g * 100 + c)
    x = jnp.asarray(rng.normal(size=(g, c, d)), dtype)
    mask = jnp.asarray(rng.random((g, c)) < 0.7)
    got = segment_reduce(x, mask)
    want = segment_reduce_ref(x, mask)
    # float32 tolerance admits summation-order noise on long segments
    # (c=32 rows: kernel accumulates in a different order than the oracle)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == "bfloat16" else 2e-5,
                               atol=1e-2 if dtype == "bfloat16" else 1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,d", [(2, 64, 32), (4, 128, 64), (1, 200, 128),
                                    (3, 256, 16)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_causal(bh, t, d, dtype):
    rng = np.random.default_rng(bh * 31 + t)
    q = jnp.asarray(rng.normal(size=(bh, t, d)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, t, d)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, t, d)), dtype)
    got = flash_attention(q, k, v, causal=True)
    want = flash_attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_bidirectional():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# topk compress
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,d,k", [(1, 16, 4), (8, 256, 32), (5, 100, 10)])
def test_topk_values_match(r, d, k):
    rng = np.random.default_rng(r * 7 + d)
    x = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    vals, idx = topk_compress(x, k)
    rvals, ridx = topk_compress_ref(x, k)
    # identical index sets & values (deterministic tie-break)
    np.testing.assert_array_equal(np.sort(np.asarray(idx), axis=1),
                                  np.sort(np.asarray(ridx), axis=1))
    np.testing.assert_allclose(
        np.sort(np.abs(np.asarray(vals)), axis=1),
        np.sort(np.abs(np.asarray(rvals)), axis=1), rtol=1e-6)


def test_topk_roundtrip_preserves_topk_energy():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    vals, idx = topk_compress(x, 16)
    dense = decompress(vals, idx, 64)
    # each kept coordinate matches, others zero
    kept = np.zeros((4, 64), bool)
    kept[np.arange(4)[:, None], np.asarray(idx)] = True
    np.testing.assert_allclose(np.asarray(dense)[kept],
                               np.asarray(x)[kept], rtol=1e-6)
    assert np.all(np.asarray(dense)[~kept] == 0)


# ---------------------------------------------------------------------------
# ssm_scan: chunked selective-SSM scan (the §Perf hymba hot path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d,n,chunk", [
    (1, 16, 8, 4, 8), (2, 32, 16, 4, 8), (3, 64, 24, 8, 16),
    (2, 32, 16, 4, 32),
])
def test_ssm_chunk_scan_matches_ref(b, t, d, n, chunk):
    from repro.kernels.ssm_scan import ssm_chunk_scan
    from repro.kernels.ssm_scan.ref import ssm_chunk_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + t), 6)
    u = jax.random.normal(ks[0], (b, t, d))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, t, 1)) - 2)
    bv = jax.random.normal(ks[2], (b, t, n))
    cv = jax.random.normal(ks[3], (b, t, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.3)
    s0 = jax.random.normal(ks[5], (b, d, n))
    y_ref, s_ref = ssm_chunk_scan_ref(u, delta, bv, cv, a, s0)
    y, s = ssm_chunk_scan(u, delta, bv, cv, a, s0, chunk=chunk,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)


def test_ssm_chunk_scan_matches_model_forward():
    """Kernel == models/ssm.py chunkwise forward on the same weights."""
    from repro.configs import ARCHS
    from repro.kernels.ssm_scan import ssm_chunk_scan
    from repro.models import ssm as mssm
    cfg = ARCHS["hymba-1.5b"].reduced(chunk_size=8)
    p = mssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_model, st = mssm.mamba_forward(p, x, cfg)
    # reproduce the pre-scan projections, then run the kernel for the scan
    u, z = jnp.split(x @ p["w_in"], 2, axis=-1)
    bcdt = (u @ p["w_bcdt"]).astype(jnp.float32)
    N = cfg.ssm_state
    bv, cv = bcdt[..., :N], bcdt[..., N:2 * N]
    delta = jax.nn.softplus(bcdt[..., -1:] + p["dt_bias"][None, None, :1])
    a = -jnp.exp(p["a_log"])
    s0 = jnp.zeros((2, u.shape[-1], N))
    y, s_f = ssm_chunk_scan(u.astype(jnp.float32), delta, bv, cv, a, s0,
                            chunk=8, interpret=True)
    y = y + p["d_skip"] * u.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y.astype(x.dtype) @ p["w_out"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_model),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(st["s"]),
                               rtol=2e-4, atol=2e-5)
