"""sdpa_blocked (online-softmax tiles) == sdpa (materialized scores)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import causal_mask, sdpa, sdpa_blocked


def _qkv(B=2, T=256, H=4, Hkv=2, D=16, Dv=16, S=None, seed=0):
    S = S or T
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dv), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [0, 100, 64])
def test_causal_blocked_matches_sdpa(window):
    q, k, v = _qkv()
    scale = 0.25
    mask = causal_mask(256, 256, window)[None]
    want = sdpa(q, k, v, mask, scale)
    got = sdpa_blocked(q, k, v, scale, causal=True, window=window, block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_noncausal_blocked_matches_sdpa():
    q, k, v = _qkv(T=128, S=256)
    mask = jnp.ones((1, 128, 256), bool)
    want = sdpa(q, k, v, mask, 0.125)
    got = sdpa_blocked(q, k, v, 0.125, causal=False, block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gqa_grouping_and_grads():
    q, k, v = _qkv(H=8, Hkv=2)
    scale = 0.25
    mask = causal_mask(256, 256)[None]

    def f_ref(q):
        return jnp.sum(sdpa(q, k, v, mask, scale) ** 2)

    def f_blk(q):
        return jnp.sum(sdpa_blocked(q, k, v, scale, block=128) ** 2)

    np.testing.assert_allclose(float(f_blk(q)), float(f_ref(q)), rtol=1e-5)
    g_ref = jax.grad(f_ref)(q)
    g_blk = jax.grad(f_blk)(q)
    np.testing.assert_allclose(np.asarray(g_blk), np.asarray(g_ref),
                               rtol=2e-4, atol=2e-4)


def test_window_larger_than_block():
    q, k, v = _qkv(T=512)
    window = 200                              # spans 4 blocks of 64
    mask = causal_mask(512, 512, window)[None]
    want = sdpa(q, k, v, mask, 0.25)
    got = sdpa_blocked(q, k, v, 0.25, causal=True, window=window, block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
