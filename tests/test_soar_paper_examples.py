"""Worked examples from the paper (Figs. 1, 2, 3) as exact regression tests."""
import numpy as np
import pytest

from repro.core import baselines
from repro.core.reduce import all_blue, all_red, mask_from_set, phi, phi_barrier
from repro.core.soar import soar
from repro.core.tree import DEST, Tree


def fig2_tree():
    """BT over 7 switches, unit rates; leaf loads (2, 6, 5, 4)."""
    parent = np.array([DEST, 0, 0, 1, 1, 2, 2])
    t = Tree(parent, np.ones(7))
    load = np.zeros(7, dtype=np.int64)
    load[[3, 4, 5, 6]] = [2, 6, 5, 4]
    return t, load


def test_fig2_strategy_costs():
    t, load = fig2_tree()
    # Subfigure captions: Top=27, Max=24, Level=21, SOAR(optimal)=20, k=2.
    assert phi(t, load, baselines.top(t, load, 2)) == 27
    assert phi(t, load, baselines.max_load(t, load, 2)) == 24
    assert phi(t, load, baselines.level(t, load, 2)) == 21
    res = soar(t, load, 2)
    assert res.cost == 20
    assert phi(t, load, res.blue) == 20
    assert res.blue.sum() <= 2


def test_fig3_increasing_k():
    t, load = fig2_tree()
    # Fig. 3: optimal costs 35, 20, 15, 11 for k = 1, 2, 3, 4.
    for k, want in [(1, 35), (2, 20), (3, 15), (4, 11)]:
        res = soar(t, load, k)
        assert res.cost == want, (k, res.cost)
        assert phi(t, load, res.blue) == want

    # k=2 and k=3 optima are stated to be unique; check the k=2 one matches
    # the Eq. (3) illustration: U = {load-6 leaf, right mid switch}.
    res2 = soar(t, load, 2)
    assert set(np.nonzero(res2.blue)[0]) == {4, 2}


def test_fig2_k0_all_red_and_all_blue():
    t, load = fig2_tree()
    # all-red: leaves (17) + mids (17) + root (17) = 51
    assert phi(t, load, all_red(t)) == 51
    # all-blue: 1 message per edge = 7
    assert phi(t, load, all_blue(t)) == 7
    assert soar(t, load, 0).cost == 51


def test_eq3_barrier_equivalence_on_example():
    t, load = fig2_tree()
    U = mask_from_set(t, [4, 2])
    assert phi(t, load, U) == 20
    assert phi_barrier(t, load, U) == 20


def test_fig1_six_server_example():
    """Fig. 1: all-red = 14 messages, all-blue = 5 (number of tree edges)."""
    # Destination d <- root r; r has two subtrees; 6 servers total, 5 switches.
    # The figure's exact topology isn't fully specified; we use a 5-switch
    # tree where the all-red utilization is 14 and all-blue is 5:
    #   r(0) -- s1(1), s2(2); s1 -- s3(3), s4(4); loads: s2=4, s3=1, s4=1.
    parent = np.array([DEST, 0, 0, 1, 1])
    t = Tree(parent, np.ones(5))
    load = np.array([0, 0, 4, 1, 1])
    assert phi(t, load, all_red(t)) == 14
    assert phi(t, load, all_blue(t)) == 5
