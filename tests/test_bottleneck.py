"""lambda-BIC (bottleneck objective, paper §8) — exactness + sanity."""
import itertools

import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.core.bottleneck import bottleneck_phi, solve_bottleneck
from repro.core.reduce import all_blue, all_red, mask_from_set
from repro.core.soar_fast import soar_fast
from repro.core.tree import DEST, Tree, bt, random_tree, sample_load


def brute_lambda(t, load, k, avail=None):
    availm = np.ones(t.n, bool) if avail is None else np.asarray(avail, bool)
    cand = np.nonzero(availm)[0]
    best = np.inf
    for size in range(min(k, len(cand)) + 1):
        for combo in itertools.combinations(cand, size):
            c = bottleneck_phi(t, load, mask_from_set(t, combo))
            best = min(best, c)
    return best


def test_fig2_bottleneck():
    parent = np.array([DEST, 0, 0, 1, 1, 2, 2])
    t = Tree(parent, np.ones(7))
    load = np.zeros(7, dtype=np.int64)
    load[[3, 4, 5, 6]] = [2, 6, 5, 4]
    # all-red: root edge carries 17 messages
    assert bottleneck_phi(t, load, all_red(t)) == 17
    assert bottleneck_phi(t, load, all_blue(t)) == 1
    blue, lam = solve_bottleneck(t, load, 2)
    assert lam == brute_lambda(t, load, 2)
    assert bottleneck_phi(t, load, blue) == lam
    assert blue.sum() <= 2


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 10), st.integers(0, 4))
def test_matches_brute_force_random(seed, n, k):
    t = random_tree(n, seed=seed)
    rng = np.random.default_rng(seed)
    load = rng.integers(0, 6, size=n)
    blue, lam = solve_bottleneck(t, load, k)
    assert blue.sum() <= k
    assert bottleneck_phi(t, load, blue) == pytest.approx(lam)
    assert lam == pytest.approx(brute_lambda(t, load, k))


def test_availability_respected():
    t = bt(16, "constant")
    load = sample_load(t, "power-law", seed=1)
    avail = np.zeros(t.n, bool)
    avail[[3, 5]] = True
    blue, lam = solve_bottleneck(t, load, 2, avail=avail)
    assert set(np.nonzero(blue)[0]) <= {3, 5}
    assert lam == pytest.approx(brute_lambda(t, load, 2, avail=avail))


def test_monotone_in_k():
    t = bt(32, "exponential")
    load = sample_load(t, "power-law", seed=2)
    prev = np.inf
    for k in range(0, 6):
        _, lam = solve_bottleneck(t, load, k)
        assert lam <= prev + 1e-12
        prev = lam


def test_conjecture_direction_smallcase():
    """phi-optimal placement should be a decent lambda solution (§8)."""
    t = bt(64, "constant")
    load = sample_load(t, "power-law", seed=3)
    k = 4
    blue_phi = soar_fast(t, load, k).blue
    _, lam_opt = solve_bottleneck(t, load, k)
    lam_phi = bottleneck_phi(t, load, blue_phi)
    assert lam_phi <= 4 * lam_opt  # loose sanity; bench quantifies tightly
