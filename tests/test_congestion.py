"""Congestion measurement + repeated-solve driver: parity, brute-force
min-max optimality on small trees, per-round bit-identity vs serial SOAR,
and the stack wiring (plan_congestion / orchestrator admission)."""
from itertools import combinations, product

import numpy as np
import pytest

from repro.collectives import fleet_tree, plan_congestion
from repro.core import bt
from repro.core.congestion import (congestion_profile, max_congestion,
                                   messages_up_batch, messages_up_forest)
from repro.core.forest import build_forest
from repro.core.reduce import phi
from repro.core.soar import soar
from repro.core.tree import DEST, Tree, sample_load
from repro.engine import EngineOptions, solve_batch, solve_congestion
from repro.runtime import Orchestrator, OrchestratorConfig


def _random_tree(rng, n_lo=5, n_hi=8):
    n = int(rng.integers(n_lo, n_hi))
    parent = np.full(n, DEST, np.int32)
    for v in range(1, n):
        parent[v] = int(rng.integers(0, v))
    return Tree(parent, rng.integers(1, 9, n) / 4.0)


# ---------------------------------------------------------------------------
# link-load kernel: device sweep bit-identical to the host reference
# ---------------------------------------------------------------------------

def test_messages_up_forest_bit_identical_to_host():
    rng = np.random.default_rng(3)
    trees, loads, blues = [], [], []
    for _ in range(12):
        n = int(rng.integers(1, 25))
        parent = np.full(n, DEST, np.int32)
        for v in range(1, n):
            parent[v] = int(rng.integers(0, v))
        trees.append(Tree(parent, rng.integers(1, 32, n) / 8.0))
        loads.append(rng.integers(0, 7, n))
        blues.append(rng.random(n) < 0.3)
    f = build_forest(trees, loads)
    B, n_max = f.mask.shape
    blue_pad = np.zeros((B, n_max), bool)
    for b, u in enumerate(blues):
        blue_pad[b, : len(u)] = u
    dev = messages_up_forest(f, blue_pad)
    for b, (t, L, u) in enumerate(zip(trees, loads, blues)):
        host = messages_up_batch([t], [L], [u])[0]
        assert np.array_equal(dev[b, : t.n], host)     # bit-identical
        assert dev[b, t.n :].sum() == 0                # padding stays zero


def test_congestion_profile_shapes_and_weighting():
    t = bt(16, "constant")
    loads = [sample_load(t, "uniform", seed=s) for s in range(3)]
    blues = [np.zeros(t.n, bool)] * 3
    msgs = messages_up_batch([t] * 3, loads, blues)
    count = congestion_profile(msgs)
    timew = congestion_profile(msgs, t.rho)
    assert count.shape == timew.shape == (t.n,)
    assert np.array_equal(timew, count * t.rho)


# ---------------------------------------------------------------------------
# driver vs brute-force min-max-congestion enumeration (small trees)
# ---------------------------------------------------------------------------

def _brute_minmax(t, loads, k):
    """min over all per-tenant (<= k)-subsets of the max-link congestion."""
    subs = []
    for sz in range(k + 1):
        for c in combinations(range(t.n), sz):
            m = np.zeros(t.n, bool)
            m[list(c)] = True
            subs.append(m)
    best = None
    for combo in product(subs, repeat=len(loads)):
        prof = congestion_profile(
            messages_up_batch([t] * len(loads), loads, list(combo)))
        best = prof.max() if best is None else min(best, prof.max())
    return int(best)


@pytest.mark.parametrize("seed", [0, 1, 2, 5])
def test_driver_achieves_bruteforce_minmax(seed):
    """On these small 2-tenant instances the penalty loop reaches the true
    min-max-congestion optimum (and strictly beats utilization-only)."""
    rng = np.random.default_rng(seed)
    t = _random_tree(rng)
    loads = [rng.integers(0, 5, t.n) for _ in range(2)]
    res = solve_congestion(t, loads, 1, max_rounds=10, patience=3)
    opt = _brute_minmax(t, loads, 1)
    assert res.max_congestion == opt
    assert res.max_congestion < res.baseline_max       # strict improvement


@pytest.mark.parametrize("seed", range(8))
def test_driver_sandwiched_by_brute_and_baseline(seed):
    """brute optimum <= driver <= utilization-only baseline, always."""
    rng = np.random.default_rng(seed)
    t = _random_tree(rng)
    loads = [rng.integers(0, 5, t.n) for _ in range(2)]
    res = solve_congestion(t, loads, 1, max_rounds=10, patience=3)
    assert _brute_minmax(t, loads, 1) <= res.max_congestion
    assert res.max_congestion <= res.baseline_max


# ---------------------------------------------------------------------------
# per-round placements bit-identical to serial soar on the reweighted rho
# ---------------------------------------------------------------------------

def test_per_round_placements_bit_identical_to_serial_soar():
    """Each round's batched solve must equal serial `soar` run per tenant
    on the same penalty-reweighted (dyadic-quantized) rho — exact equality
    of masks, not approximate (see engine/batched.py numerics note)."""
    t = bt(32, "constant")
    loads = [sample_load(t, "power-law", seed=s) for s in range(6)]
    res = solve_congestion(t, loads, 4, record_rounds=True)
    assert len(res.rounds_log) == res.rounds >= 2
    for r, (rho_eff, blue) in enumerate(res.rounds_log):
        for ti, L in enumerate(loads):
            ref = soar(Tree(t.parent, rho_eff[ti]), L, 4)
            assert np.array_equal(blue[ti], ref.blue), (r, ti)
    # round 0 runs on the unweighted tree
    assert np.array_equal(res.rounds_log[0][0],
                          np.broadcast_to(t.rho, res.rounds_log[0][0].shape))


# ---------------------------------------------------------------------------
# fleet scenario: measurable reduction, convergence, monotone best
# ---------------------------------------------------------------------------

def test_fleet_scenario_reduction_and_convergence():
    """Acceptance: at T >= 16 the driver cuts max-link congestion >= 15%
    vs utilization-only solve_batch, within the round bound, and the
    result is the best round seen (monotone-best tracking)."""
    t = bt(128, "constant")
    T, k, max_rounds = 16, 8, 8
    loads = [sample_load(t, "power-law", seed=s) for s in range(T)]
    res = solve_congestion(t, loads, k, max_rounds=max_rounds)
    assert res.improvement >= 0.15
    # converged: the final round did not improve (plateau reached within
    # the budget), not merely "ran out of rounds mid-descent"
    assert res.best_round < res.rounds - 1 <= max_rounds - 1
    assert res.max_congestion == min(res.history)      # monotone best
    assert res.history[0] == res.baseline_max
    # round 0 is exactly the utilization-only batched solve
    base = solve_batch([t] * T, loads, k)
    prof0 = congestion_profile(
        messages_up_batch([t] * T, loads, [base.blue_of(b)
                                           for b in range(T)]))
    assert res.baseline_max == prof0.max()
    # every tenant keeps a valid budget-k placement, costed on original rho
    for ti, L in enumerate(loads):
        assert res.blue[ti].sum() <= k
        assert res.costs[ti] == phi(t, L, res.blue[ti])
    # the reported profile matches the masks it ships
    prof = congestion_profile(
        messages_up_batch([t] * T, loads, list(res.blue)))
    assert np.array_equal(prof, res.congestion)
    assert res.max_congestion == max_congestion(t, loads, list(res.blue))


def test_driver_input_validation():
    t = bt(16, "constant")
    L = sample_load(t, "uniform", seed=0)
    with pytest.raises(ValueError):
        solve_congestion(t, [], 2)
    with pytest.raises(ValueError):
        solve_congestion(t, [L], 2, max_rounds=0)
    with pytest.raises(ValueError):
        solve_congestion(t, [L], 2, options=EngineOptions(color=False))
    with pytest.raises(ValueError):
        solve_congestion(t, [L], 2, options=EngineOptions(debug_tables=True))
    with pytest.raises(ValueError):
        solve_congestion(t, [L, L], 2, avail=[None])
    with pytest.raises(ValueError):
        solve_congestion(t, [L], 2, capacity=np.ones(3))   # shape != (n,)


def test_rho_weighted_congestion_mode():
    t = bt(32, "linear")
    loads = [sample_load(t, "power-law", seed=s) for s in range(4)]
    res = solve_congestion(t, loads, 3, rho_weighted=True)
    assert res.max_congestion == pytest.approx(
        max_congestion(t, loads, list(res.blue), rho_weighted=True))


# ---------------------------------------------------------------------------
# stack wiring: plan_congestion and orchestrator admission
# ---------------------------------------------------------------------------

def test_plan_congestion_builds_consistent_programs():
    topo = fleet_tree(2, 4, 4)
    rng = np.random.default_rng(5)
    loads = []
    for _ in range(6):
        L = topo.load.copy()
        # each tenant runs on a random subset of the racks
        L[rng.random(topo.tree.n) < 0.4] = 0
        loads.append(L)
    planned, res = plan_congestion(topo, 3, loads=loads)
    assert len(planned) == 6
    for (blue, prog), L, cost in zip(planned, loads, res.costs):
        assert prog.utilization == pytest.approx(phi(topo.tree, L, blue))
        assert prog.utilization == pytest.approx(cost)
        assert blue.sum() <= 3
    with pytest.raises(ValueError):
        plan_congestion(topo, 3)                       # loads xor count
    with pytest.raises(ValueError):
        plan_congestion(topo, 3, loads=loads, count=6)


def test_orchestrator_congestion_aware_admission():
    topo = fleet_tree(2, 4, 4)
    # capacity 8 >= 1 + 4 admitted workloads: no collision fallback fires,
    # so the admitted fleet is exactly the driver's (monotone-best) output
    orch = Orchestrator(topo, OrchestratorConfig(k=4, capacity=8))
    progs = orch.begin_workloads(4, congestion_aware=True)
    assert len(progs) == 4
    assert (orch._residual >= 0).all()                 # claims respected
    assert orch.last_congestion is not None
    assert orch.last_congestion.max_congestion <= \
        orch.last_congestion.baseline_max
    # driver options without the flag are a hard error, not silently lost
    with pytest.raises(ValueError):
        orch.begin_workloads(2, max_rounds=4)
    # congestion-aware admission is a soar-only mode
    top = Orchestrator(topo, OrchestratorConfig(k=4, capacity=3,
                                                strategy="top"))
    with pytest.raises(ValueError):
        top.begin_workloads(2, congestion_aware=True)


def test_congestion_admission_report_matches_admitted_placements():
    """With tight capacity some driver placements are replaced by collision
    fallbacks; last_congestion must then describe what was *admitted*."""
    topo = fleet_tree(2, 4, 4)
    orch = Orchestrator(topo, OrchestratorConfig(k=3, capacity=1))
    orch.begin_workloads(3, congestion_aware=True)
    assert (orch._residual >= 0).all()
    res = orch.last_congestion
    assert res.blue.shape[0] == 3
    prof = congestion_profile(messages_up_batch(
        [topo.tree] * 3, [topo.load] * 3, list(res.blue)))
    assert np.array_equal(prof, res.congestion)
    assert res.max_congestion == prof.max()
    for blue, cost in zip(res.blue, res.costs):
        assert cost == pytest.approx(phi(topo.tree, topo.load, blue))
