"""Tests for the online multi-workload allocator and byte-complexity models."""
import numpy as np
import pytest

from repro.core import (
    ParameterServerModel,
    WordCountModel,
    all_blue,
    all_red,
    byte_complexity,
    bt,
    online_allocate,
    phi,
    soar,
    workload_stream,
)


@pytest.fixture(scope="module")
def small_net():
    t = bt(32)
    return t, workload_stream(t, 8, seed=0)


def test_online_capacity_respected(small_net):
    t, ws = small_net
    res = online_allocate(t, ws, k=4, capacity=2, strategy="soar")
    used = np.zeros(t.n, dtype=np.int64)
    for p in res.picks:
        used += p.astype(np.int64)
        assert p.sum() <= 4
    assert np.all(used <= 2)
    assert np.all(res.residual_capacity == 2 - used)


def test_online_soar_beats_baselines_on_average(small_net):
    t, ws = small_net
    totals = {}
    for s in ("soar", "top", "max", "level", "random"):
        res = online_allocate(t, ws, k=4, capacity=2, strategy=s)
        totals[s] = res.costs.sum()
    # SOAR is optimal per workload given residual availability
    assert totals["soar"] <= min(v for k_, v in totals.items() if k_ != "soar") + 1e-9


def test_online_unbounded_capacity_is_per_workload_optimal(small_net):
    """Sec 5.2: with unbounded capacity SOAR stays optimal even online."""
    t, ws = small_net
    res = online_allocate(t, ws, k=4, capacity=len(ws), strategy="soar")
    for load, cost in zip(ws, res.costs):
        assert abs(cost - soar(t, load, 4).cost) < 1e-9


def test_online_saturation_tends_to_all_red(small_net):
    """With tiny capacity and many workloads, late workloads get no aggregation."""
    t, _ = small_net
    ws = workload_stream(t, 40, seed=1)
    res = online_allocate(t, ws, k=8, capacity=1, strategy="soar")
    # late normalized ratio approaches 1 (all-red)
    assert res.normalized[-1] > res.normalized[4]
    assert res.costs[-1] == pytest.approx(res.red_costs[-1])


# ---------------------------------------------------------------------------
# Byte complexity
# ---------------------------------------------------------------------------

def test_ps_model_sizes():
    ps = ParameterServerModel(features=10_000, dropout=0.5, bytes_per_kv=1)
    assert ps.size(1) == pytest.approx(5000.0)
    assert ps.size(2) == pytest.approx(7500.0)
    # union saturates at the full feature space
    assert ps.size(50) == pytest.approx(10_000.0, rel=1e-6)


def test_wc_model_monotone_sublinear():
    wc = WordCountModel(total_words=100_000, vocab=5_000, n_servers=100,
                        bytes_per_kv=1)
    s1, s2, s4 = wc.size(1), wc.size(2), wc.size(4)
    assert s1 < s2 < s4          # unions grow
    assert s2 < 2 * s1           # but sub-additively (shared hot words)
    assert s4 <= 5_000           # bounded by vocab


def test_byte_complexity_red_vs_blue():
    t = bt(16)
    load = np.zeros(t.n, dtype=np.int64)
    load[t.leaves] = 4
    ps = ParameterServerModel()
    red = byte_complexity(t, load, all_red(t), ps.size)
    blue = byte_complexity(t, load, all_blue(t), ps.size)
    assert blue < red
    # all-red bytes = sum over servers of size(1) * path length (rho=1)
    depth_cost = sum((t.depth[v] + 1) * load[v] for v in t.leaves)
    assert red == pytest.approx(ps.size(1) * depth_cost)


def test_byte_complexity_soar_between_extremes():
    t = bt(64)
    rng = np.random.default_rng(0)
    load = np.zeros(t.n, dtype=np.int64)
    load[t.leaves] = rng.integers(1, 10, size=len(t.leaves))
    wc = WordCountModel(total_words=200_000, vocab=10_000, n_servers=200)
    res = soar(t, load, 6)
    b = byte_complexity(t, load, res.blue, wc.size)
    assert byte_complexity(t, load, all_blue(t), wc.size) <= b + 1e-6
    assert b <= byte_complexity(t, load, all_red(t), wc.size) + 1e-6
