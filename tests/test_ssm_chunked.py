"""Chunkwise-parallel Mamba scan == sequential reference (the §Perf hymba
optimization must not change semantics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import ssm


def _cfg(chunk=8):
    return ARCHS["hymba-1.5b"].reduced(chunk_size=chunk)


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (16, 16), (8, 64)])
def test_chunked_equals_sequential(T, chunk):
    cfg = _cfg(chunk)
    key = jax.random.PRNGKey(0)
    p = ssm.init_mamba(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, cfg.d_model),
                          jnp.float32)
    y_seq, st_seq = ssm.mamba_forward_sequential(p, x, cfg)
    y_chk, st_chk = ssm.mamba_forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_chk["s"]),
                               np.asarray(st_seq["s"]), rtol=2e-4, atol=2e-5)


def test_chunked_with_carry_state():
    cfg = _cfg(8)
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 48, cfg.d_model),
                          jnp.float32)
    # run the first half, carry the state, run the second half
    y1, st = ssm.mamba_forward(p, x[:, :24], cfg)
    y2, st2 = ssm.mamba_forward(p, x[:, 24:], cfg, state=st)
    y_all, st_all = ssm.mamba_forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st2["s"]), np.asarray(st_all["s"]),
                               rtol=2e-4, atol=2e-5)


def test_decode_consistent_with_forward():
    cfg = _cfg(8)
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model),
                          jnp.float32)
    y_fwd, st_fwd = ssm.mamba_forward(p, x, cfg)
    st = ssm.mamba_state(cfg, 1)
    ys = []
    for t in range(8):
        y, st = ssm.mamba_decode(p, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_fwd),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st["s"]), np.asarray(st_fwd["s"]),
                               rtol=2e-4, atol=2e-5)


def test_grads_flow_and_finite():
    cfg = _cfg(8)
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model),
                          jnp.float32)

    def loss(p):
        y, _ = ssm.mamba_forward(p, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
    assert float(ssm_gnorm(g)) > 0


def ssm_gnorm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                        for v in jax.tree.leaves(tree)))
