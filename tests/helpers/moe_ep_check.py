"""Subprocess helper: EP (shard_map all-to-all) MoE == dense-dispatch MoE.

Run directly:  PYTHONPATH=src python tests/helpers/moe_ep_check.py
Forced device count must precede jax init, hence a separate process.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import moe
from repro.parallel.sharding import axis_rules, make_rules


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # high capacity factor -> no drops -> EP must match dense exactly
    cfg = ARCHS["deepseek-v2-236b"].reduced(
        n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0,
        n_shared_experts=1, dtype="float32")
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    B, T = 4, 16                                       # N=64, divisible by 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                          jnp.float32)

    y_dense, aux_dense = jax.jit(
        lambda p, x: moe._moe_forward_dense(p, x, cfg))(p, x)

    def loss_dense(p):
        y, aux = moe._moe_forward_dense(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    def loss_ep(p):
        y, aux = moe.moe_forward(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g_dense = jax.jit(jax.grad(loss_dense))(p)
    rules = make_rules(multi_pod=False)
    key = lambda kv: str(kv[0])

    for mode in ("replicated", "a2a"):
        moe.EP_MODE = mode
        with mesh, axis_rules(rules, mesh):
            y_ep, aux_ep = jax.jit(
                lambda p, x: moe.moe_forward(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                                   rtol=2e-5, atol=2e-5, err_msg=mode)
        np.testing.assert_allclose(float(aux_ep), float(aux_dense),
                                   rtol=1e-5, err_msg=mode)
        with mesh, axis_rules(rules, mesh):
            g_ep = jax.jit(jax.grad(loss_ep))(p)
        for (kd, ld), (ke, le) in zip(
                sorted(jax.tree_util.tree_leaves_with_path(g_dense), key=key),
                sorted(jax.tree_util.tree_leaves_with_path(g_ep), key=key)):
            np.testing.assert_allclose(np.asarray(le), np.asarray(ld),
                                       rtol=5e-4, atol=5e-5,
                                       err_msg=f"{mode} {kd}")
    moe.EP_MODE = "replicated"
    print("MOE_EP_CHECK_OK")


if __name__ == "__main__":
    main()
