"""Subprocess helper: degraded-mode execution on 8 fake devices.

Two checks that need a real multi-device mesh (forced device count must
be set before jax initializes, hence a separate process):

  1. **executor bitwise identity** — for random placements and random
     partial-capacity degradations, the shard_map executor's degraded
     (spilling) program returns the global sum *bit-for-bit* equal to
     the fault-free program's;
  2. **training-coupled chaos** — a ChaosTrainer over the 8-device dp
     fleet steps through degrade/crash events with every lossless
     recovery asserted bit-identical at the full optimizer-step level
     and checkpoint restarts verified.

Run directly:  PYTHONPATH=src python tests/helpers/degraded_check.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives import (build_program, chip_level_tree,
                               degrade_switches, tree_allreduce)


def check_executor_bitwise():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("data",))
    topo = chip_level_tree(n_pods=2, racks_per_pod=2, chips_per_rack=2)
    t = topo.tree
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)

    checked = 0
    for trial in range(8):
        blue = rng.random(t.n) < 0.5
        with mesh:
            ref = np.asarray(tree_allreduce(x, build_program(topo, blue),
                                            mesh, "data"))
        np.testing.assert_allclose(ref, np.asarray(x).sum(0), rtol=1e-5,
                                   atol=1e-5)
        ks = rng.choice(t.n, size=int(rng.integers(1, 4)), replace=False)
        scales = {int(s): float(rng.choice([0.75, 0.5, 0.25, 0.05]))
                  for s in ks}
        td = degrade_switches(topo, scales)
        pd = build_program(td, blue)
        with mesh:
            got = np.asarray(tree_allreduce(x, pd, mesh, "data"))
        assert got.tobytes() == ref.tobytes(), (trial, scales)
        checked += 1
    # degraded root: overflow completes at the destination
    blue = np.ones(t.n, bool)
    with mesh:
        ref = np.asarray(tree_allreduce(x, build_program(topo, blue),
                                        mesh, "data"))
    td = degrade_switches(topo, {int(t.root): 0.05})
    pd = build_program(td, blue)
    assert pd.root_count > 1
    with mesh:
        got = np.asarray(tree_allreduce(x, pd, mesh, "data"))
    assert got.tobytes() == ref.tobytes()
    print(f"executor: {checked + 1} degraded cases bitwise-identical")


def check_training_coupled_chaos():
    from repro.launch.train import dp_fleet
    from repro.runtime import (ChaosHarness, ChaosTrainer, Orchestrator,
                               OrchestratorConfig)
    from repro.runtime.faults import FaultEvent

    topo = dp_fleet(8)
    orch = Orchestrator(topo, OrchestratorConfig(k=2))
    blue = [int(s) for s in np.nonzero(orch.blue)[0]]
    with tempfile.TemporaryDirectory() as d:
        trainer = ChaosTrainer(orch, seq=16, global_batch=8, ckpt_dir=d,
                               ckpt_every=2)
        h = ChaosHarness(orch, trainer=trainer)
        events = [
            FaultEvent("degrade_switch", rates=((blue[0], 0.5),)),
            FaultEvent("degrade_switch", rates=((blue[1], 0.25),)),
            FaultEvent("crash"),
            FaultEvent("recover_switch_capacity", rates=((blue[0], 1.0),)),
            FaultEvent("fail_device", devices=(3,)),
            FaultEvent("crash"),
            FaultEvent("recover_device", devices=(3,)),
            FaultEvent("recover_switch_capacity", rates=((blue[1], 1.0),)),
        ]
        report = h.run(events)
    tr = report.train
    assert tr["steps"] == len(events), tr
    assert tr["restores"] == 2, tr
    # the two blue degrades kept placement + devices -> bitwise-checked
    assert tr["bitwise_checks"] >= 2, tr
    assert report.invariant_checks == len(events)
    print(f"train: {tr['steps']} steps, {tr['bitwise_checks']} bitwise "
          f"checks, {tr['restores']} restarts, loss {tr['first_loss']:.3f} "
          f"-> {tr['last_loss']:.3f}")


def main():
    check_executor_bitwise()
    check_training_coupled_chaos()
    print("DEGRADED_CHECK_OK")


if __name__ == "__main__":
    main()
