"""Subprocess helper: validate tree_allreduce == psum on 8 fake devices.

Run directly:  PYTHONPATH=src python tests/helpers/collective_check.py
(The forced device count must be set before jax initializes, hence a
separate process from the main pytest run.)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives import (
    build_program, chip_level_tree, fail_devices, plan, tree_allreduce,
)
from repro.core.reduce import all_blue, all_red


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("data",))
    topo = chip_level_tree(n_pods=2, racks_per_pod=2, chips_per_rack=2)
    assert topo.n_devices == 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    want = np.asarray(x).sum(0)

    checked = 0
    for k in (0, 1, 2, 4, topo.tree.n):
        for strategy in ("soar", "top", "max", "random"):
            blue, prog = plan(topo, k, strategy=strategy)
            with mesh:
                got = tree_allreduce(x, prog, mesh, "data")
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                       atol=1e-5)
            checked += 1
    # extremes
    for blue in (all_red(topo.tree), all_blue(topo.tree)):
        prog = build_program(topo, blue)
        with mesh:
            got = tree_allreduce(x, prog, mesh, "data")
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
        checked += 1

    # SOAR cost dominance across programs at equal budget
    _, p_soar = plan(topo, 2, strategy="soar")
    for s in ("top", "max", "random"):
        _, p_other = plan(topo, 2, strategy=s)
        assert p_soar.utilization <= p_other.utilization + 1e-9

    # fault tolerance: kill two chips, re-plan, reduce the survivors
    dead = [3, 6]
    topo2 = fail_devices(topo, dead)
    blue2, prog2 = plan(topo2, 2, strategy="soar")
    x2 = np.asarray(x).copy()
    x2[dead] = 0.0  # dead devices contribute nothing
    with mesh:
        got = tree_allreduce(jnp.asarray(x2), prog2, mesh, "data")
    np.testing.assert_allclose(np.asarray(got), x2.sum(0), rtol=1e-5,
                               atol=1e-5)
    checked += 1
    print(f"COLLECTIVE_CHECK_OK checked={checked}")


if __name__ == "__main__":
    main()
